//! Chaos suite: fault schedules driven end to end. Crash-mid-epoch
//! recovery cross-checked against a from-scratch recompute, corrupt and
//! torn WAL matrices (insert and delete frames, plus pre-deletion v1/v2
//! format compatibility), injected WAL I/O errors, pool-job panics isolated
//! to their own request, request deadlines, connection drops
//! mid-pipeline, idle/drain closes, hostile binary frames on a live
//! socket, and an env-driven soak (`CONTOUR_FAULTS`, used by the CI
//! chaos job) that must leave the server answering once faults clear.
//!
//! The failpoint registry is process-global, so every test holds
//! [`faults::test_lock`] for its whole body (via [`quiesce`]) — the
//! suite is deliberately serialized.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use contour::cc::{contour::Contour, Algorithm, Labels};
use contour::graph::{gen, EdgeList};
use contour::server::{protocol, serve_listener, ServerState, Session};
use contour::stream::{Snapshot, StreamingCc, Wal};
use contour::util::{crc, faults};
use contour::VId;

// ---------------------------------------------------------- harness

/// Serialize the suite and disarm any leftover schedule. Forces the
/// lazy `CONTOUR_FAULTS` env load *before* clearing: clearing first
/// would let a later failpoint evaluation arm the env schedule
/// mid-test. The soak test re-reads the env var explicitly.
fn quiesce() -> std::sync::MutexGuard<'static, ()> {
    let g = faults::test_lock();
    let _ = faults::active();
    faults::clear();
    g
}

fn no_body() -> anyhow::Result<String> {
    anyhow::bail!("no extra payload expected")
}

fn ask(state: &ServerState, line: &str) -> String {
    Session::new(state).handle(line, no_body).unwrap_or_else(|| "BYE".into())
}

type ServerHandle = (String, Arc<AtomicBool>, std::thread::JoinHandle<anyhow::Result<()>>);

fn spawn_server(state: Arc<ServerState>) -> ServerHandle {
    let shutdown = Arc::new(AtomicBool::new(false));
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("local_addr").to_string();
    let sd = Arc::clone(&shutdown);
    let handle = std::thread::spawn(move || serve_listener(listener, state, sd));
    (addr, shutdown, handle)
}

fn stop(shutdown: &AtomicBool, handle: std::thread::JoinHandle<anyhow::Result<()>>) {
    shutdown.store(true, Ordering::Relaxed);
    handle.join().unwrap().unwrap();
}

/// Line-protocol client whose reads time out instead of hanging the
/// suite: a lost reply (injected `conn.write` drop, server close)
/// surfaces as `Err` or an empty line, never a stuck test.
struct Wire {
    r: BufReader<TcpStream>,
    w: TcpStream,
}

impl Wire {
    fn connect(addr: &str) -> std::io::Result<Self> {
        let s = TcpStream::connect(addr)?;
        s.set_read_timeout(Some(Duration::from_secs(10)))?;
        Ok(Self { r: BufReader::new(s.try_clone()?), w: s })
    }

    fn try_ask(&mut self, msg: &str) -> std::io::Result<String> {
        self.w.write_all(msg.as_bytes())?;
        self.w.write_all(b"\n")?;
        self.read_line()
    }

    fn ask(&mut self, msg: &str) -> String {
        self.try_ask(msg).unwrap_or_else(|e| panic!("{msg:?}: connection lost: {e}"))
    }

    /// One reply line; `Ok("")` is the server closing the connection.
    fn read_line(&mut self) -> std::io::Result<String> {
        let mut reply = String::new();
        self.r.read_line(&mut reply)?;
        Ok(reply.trim_end().to_string())
    }
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("contour-chaos-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Ground truth: static min-id-canonical Contour labels on an edge set.
fn labels_of(n: usize, edges: &[(VId, VId)]) -> Labels {
    Contour::c2().run(&EdgeList::from_pairs(n, edges).into_csr())
}

fn flip_byte(path: &std::path::Path, off: usize) {
    let mut data = std::fs::read(path).unwrap();
    assert!(off < data.len(), "flip offset {off} past {} bytes", data.len());
    data[off] ^= 0xFF;
    std::fs::write(path, data).unwrap();
}

/// Hand-build a pre-deletion WAL image — v1 (`CONTRWAL`, no CRCs) or
/// v2 (`CONTRWL2`, per-frame CRC) — holding only insert frames. The
/// equivalent helpers in stream/wal.rs live in its private test module,
/// so the compat tests here forge the bytes themselves.
fn write_legacy_wal(path: &std::path::Path, ver: u8, n: usize, frames: &[&[(VId, VId)]]) {
    assert!(ver == 1 || ver == 2);
    let mut data = Vec::new();
    data.extend_from_slice(if ver == 1 { b"CONTRWAL" } else { b"CONTRWL2" });
    data.extend_from_slice(&(n as u64).to_le_bytes());
    for pairs in frames {
        let mut frame = vec![0x01u8];
        frame.extend_from_slice(&(pairs.len() as u32).to_le_bytes());
        for &(u, v) in *pairs {
            frame.extend_from_slice(&u.to_le_bytes());
            frame.extend_from_slice(&v.to_le_bytes());
        }
        if ver >= 2 {
            frame.extend_from_slice(&crc::crc32(&frame).to_le_bytes());
        }
        data.extend_from_slice(&frame);
    }
    std::fs::write(path, data).unwrap();
}

// ------------------------------------------- durability under crashes

/// ACCEPTANCE: kill mid-epoch (unsealed WAL suffix past the last
/// snapshot), recover, and the labels are bit-identical to a
/// from-scratch recompute on everything that was acknowledged.
#[test]
fn kill_mid_epoch_recovery_is_bit_identical() {
    let _g = quiesce();
    let dir = fresh_dir("kill");
    let (wal, snap) = (dir.join("g.wal"), dir.join("g.snap"));
    let g = gen::rmat(10, 4_000, gen::RmatKind::Graph500, 11).into_csr();
    let edges: Vec<(VId, VId)> = g.edges().collect();
    let half = edges.len() / 2;
    {
        let s = StreamingCc::open(g.n, 1, Some(wal.as_path())).unwrap();
        s.add_edges(&edges[..half]).unwrap();
        s.seal_epoch().unwrap();
        s.save_snapshot(&snap).unwrap();
        s.add_edges(&edges[half..]).unwrap();
        // "Kill": dropped mid-epoch — the suffix lives only in the WAL.
    }
    let want = labels_of(g.n, &edges);

    let r = StreamingCc::recover(Some(snap.as_path()), Some(wal.as_path()), 0).unwrap();
    assert_eq!(r.current().labels, want, "snapshot + WAL suffix diverged");
    let info = r.recovery().expect("recovery stats");
    assert!(info.frames_replayed > 0, "nothing replayed past the snapshot cut");
    assert_eq!(info.truncated_bytes, 0, "clean log repaired bytes");
    let summary = info.summary();
    assert!(summary.contains("snapshot=1") && summary.contains("frames="), "{summary}");

    // The WAL alone (snapshot lost in the crash) reaches the same state.
    let r2 = StreamingCc::recover(None, Some(wal.as_path()), 0).unwrap();
    assert_eq!(r2.current().labels, want, "WAL-only recovery diverged");
}

/// A crash mid-append tears the final frame: recovery truncates exactly
/// that frame, keeps every complete one, and reports the repair.
#[test]
fn torn_wal_tail_is_truncated_and_recovered() {
    let _g = quiesce();
    let dir = fresh_dir("torn");
    let wal = dir.join("g.wal");
    let g = gen::erdos_renyi(600, 1_100, 5).into_csr();
    let edges: Vec<(VId, VId)> = g.edges().collect();
    let chunk = 64;
    {
        let s = StreamingCc::open(g.n, 0, Some(wal.as_path())).unwrap();
        for c in edges.chunks(chunk) {
            s.add_edges(c).unwrap();
        }
    }
    // Tear 3 bytes off the last frame (one frame per add_edges batch).
    let len = std::fs::metadata(&wal).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&wal).unwrap();
    f.set_len(len - 3).unwrap();
    drop(f);

    let last = edges.len() - (edges.len() - 1) % chunk - 1;
    let r = StreamingCc::recover(None, Some(wal.as_path()), 0).unwrap();
    let info = r.recovery().expect("recovery stats");
    assert!(info.truncated_bytes > 0, "torn tail not reported");
    assert_eq!(r.current().labels, labels_of(g.n, &edges[..last]), "lost more than the torn frame");

    // The repair rewound to a clean frame boundary: appending and
    // replaying again must work without re-tearing.
    let r2 = StreamingCc::recover(None, Some(wal.as_path()), 0).unwrap();
    assert_eq!(r2.recovery().unwrap().truncated_bytes, 0, "repair did not persist");
}

/// ACCEPTANCE: a corrupted (not torn) WAL frame is rejected loudly with
/// the byte offset of the bad frame — never silently dropped.
#[test]
fn corrupt_wal_frame_fails_with_byte_offset() {
    let _g = quiesce();
    let dir = fresh_dir("corrupt");
    let wal = dir.join("g.wal");
    {
        let mut w = Wal::create(&wal, 64).unwrap();
        w.append_edges(&[(0, 1), (1, 2), (2, 3)]).unwrap();
        w.append_edges(&[(4, 5), (5, 6)]).unwrap();
        w.seal_epoch(1).unwrap();
    }
    // First frame starts at byte 16 (header); flip an edge byte inside
    // its payload so the frame still parses but the CRC disagrees.
    flip_byte(&wal, 16 + 5 + 1);

    let err = Wal::replay_and_repair(&wal).unwrap_err().to_string();
    assert!(err.contains("checksum mismatch at byte 16"), "{err}");
    let err = StreamingCc::recover(None, Some(wal.as_path()), 0).unwrap_err().to_string();
    assert!(err.contains("checksum mismatch"), "recovery swallowed corruption: {err}");
}

/// A crash mid-delete-append tears the final (delete) frame: recovery
/// truncates exactly that frame — the delete never happened, because it
/// was never acknowledged — and the repaired log accepts and replays a
/// re-issued delete cleanly.
#[test]
fn torn_delete_frame_is_truncated_and_recovered() {
    let _g = quiesce();
    let dir = fresh_dir("torndel");
    let wal = dir.join("g.wal");
    let edges = [(0u32, 1u32), (1, 2), (2, 3), (10, 11)];
    {
        let s = StreamingCc::open(64, 0, Some(wal.as_path())).unwrap();
        s.add_edges(&edges).unwrap();
        s.delete_edges(&[(1, 2), (10, 11)]).unwrap();
        // "Kill" mid-append: the tear below lands inside this frame.
    }
    let len = std::fs::metadata(&wal).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&wal).unwrap();
    f.set_len(len - 3).unwrap();
    drop(f);

    let r = StreamingCc::recover(None, Some(wal.as_path()), 0).unwrap();
    let info = r.recovery().expect("recovery stats");
    assert!(info.truncated_bytes > 0, "torn delete tail not reported");
    assert_eq!(info.deletes_replayed, 0, "a torn delete frame must not replay");
    assert_eq!(r.current().labels, labels_of(64, &edges), "lost more than the torn frame");
    assert_eq!(r.edges_live(), edges.len(), "the unacknowledged delete was applied");

    // The repair rewound to a frame boundary: the delete can be
    // re-issued against the same log and replays on the next boot.
    r.delete_edges(&[(1, 2)]).unwrap();
    r.seal_epoch().unwrap();
    let survivors = [(0u32, 1u32), (2, 3), (10, 11)];
    assert_eq!(r.current().labels, labels_of(64, &survivors));
    drop(r);
    let r2 = StreamingCc::recover(None, Some(wal.as_path()), 0).unwrap();
    let info2 = r2.recovery().expect("recovery stats");
    assert_eq!(info2.truncated_bytes, 0, "repair did not persist");
    assert_eq!(info2.deletes_replayed, 1);
    assert_eq!(r2.current().labels, labels_of(64, &survivors));
}

/// ACCEPTANCE: interior corruption of a delete frame (bit flip, not a
/// tear) fails recovery loudly with the frame's byte offset.
#[test]
fn corrupt_delete_frame_fails_with_byte_offset() {
    let _g = quiesce();
    let dir = fresh_dir("delcorrupt");
    let wal = dir.join("g.wal");
    {
        let s = StreamingCc::open(64, 0, Some(wal.as_path())).unwrap();
        s.add_edges(&[(0, 1), (1, 2)]).unwrap(); // 25-byte frame at offset 16
        s.delete_edges(&[(0, 1)]).unwrap(); // 17-byte frame at offset 41
    }
    // Flip a payload byte inside the delete frame so it still parses
    // but its CRC disagrees.
    flip_byte(&wal, 41 + 5 + 1);
    let err = StreamingCc::recover(None, Some(wal.as_path()), 0).unwrap_err().to_string();
    assert!(err.contains("checksum mismatch at byte 41"), "{err}");
}

/// Pre-deletion log formats still replay end to end: v1 (no CRCs) and
/// v2 both recover into a working stream, inserts keep appending in the
/// old format, and a delete is refused cleanly — with nothing applied —
/// rather than writing a frame an old reader would misparse.
#[test]
fn legacy_wal_versions_replay_and_refuse_deletes() {
    let _g = quiesce();
    let dir = fresh_dir("legacy");
    let edges = [(0u32, 1u32), (1, 2), (4, 5)];
    for ver in [1u8, 2] {
        let wal = dir.join(format!("v{ver}.wal"));
        write_legacy_wal(&wal, ver, 64, &[&edges[..2], &edges[2..]]);
        let r = StreamingCc::recover(None, Some(wal.as_path()), 0).unwrap();
        assert_eq!(r.current().labels, labels_of(64, &edges), "v{ver} replay diverged");
        assert_eq!(r.edges_live(), edges.len());
        r.add_edges(&[(10, 11)]).unwrap();
        let err = r.delete_edges(&[(0, 1)]).unwrap_err().to_string();
        assert!(err.contains(&format!("v{ver} cannot hold delete frames")), "{err}");
        assert_eq!(r.edges_live(), 4, "refused delete must leave the batch unapplied");
        assert_eq!(r.edges_deleted(), 0);
        drop(r);
        let mut all = edges.to_vec();
        all.push((10, 11));
        let r2 = StreamingCc::recover(None, Some(wal.as_path()), 0).unwrap();
        assert_eq!(r2.current().labels, labels_of(64, &all), "v{ver} re-replay diverged");
    }
}

/// A bit flip inside a snapshot fails the trailing CRC on load.
#[test]
fn corrupt_snapshot_fails_checksum() {
    let _g = quiesce();
    let dir = fresh_dir("snapcorrupt");
    let snap = dir.join("g.snap");
    let s = StreamingCc::new(64, 0);
    s.add_edges(&[(0, 1), (2, 3), (3, 4)]).unwrap();
    s.seal_epoch().unwrap();
    s.save_snapshot(&snap).unwrap();
    flip_byte(&snap, 40);
    let err = Snapshot::load(&snap).unwrap_err().to_string();
    assert!(err.contains("checksum mismatch"), "{err}");
}

/// An injected WAL append error fails only the unacknowledged batch:
/// the live structure never applies it, so recovery agrees with what
/// the caller was told.
#[test]
fn wal_append_fault_keeps_live_and_recovered_consistent() {
    let _g = quiesce();
    let dir = fresh_dir("walerr");
    let wal = dir.join("g.wal");
    let (b1, b2, b3) = ([(0u32, 1u32), (1, 2)], [(10u32, 11u32)], [(20u32, 21u32), (21, 22)]);
    faults::configure("wal.append=err@2").unwrap();
    {
        let s = StreamingCc::open(64, 0, Some(wal.as_path())).unwrap();
        s.add_edges(&b1).unwrap();
        let err = s.add_edges(&b2).unwrap_err().to_string();
        assert!(err.contains("injected fault at wal.append"), "{err}");
        s.add_edges(&b3).unwrap();
        s.seal_epoch().unwrap();
        // Live state must exclude the failed batch...
        assert!(s.connected_live(0, 2).unwrap());
        assert!(s.connected_live(20, 22).unwrap());
        assert!(!s.connected_live(10, 11).unwrap(), "unacknowledged batch was applied");
    }
    faults::clear();
    // ...and so must recovery: the batch was never acknowledged.
    let acked: Vec<(VId, VId)> = b1.iter().chain(b3.iter()).copied().collect();
    let r = StreamingCc::recover(None, Some(wal.as_path()), 0).unwrap();
    assert_eq!(r.current().labels, labels_of(64, &acked));
}

// -------------------------------------------------- panic isolation

/// ACCEPTANCE: a pool-job panic fails only its own request as
/// `ERR internal` — the connection, other connections, and a retry of
/// the same verb all keep working, and the panic is metered.
#[test]
fn pool_panic_fails_one_request_server_keeps_answering() {
    let _g = quiesce();
    let state = Arc::new(ServerState::new(2));
    let (addr, shutdown, handle) = spawn_server(Arc::clone(&state));
    let mut c = Wire::connect(&addr).unwrap();
    assert!(c.ask("GEN g er:3000:6000").starts_with("OK 3000 "));
    assert!(c.ask("SHARD g 2").starts_with("OK "));

    faults::configure("pool.job=panic@1").unwrap();
    let r = c.ask("PCC g C-2");
    assert!(r.starts_with("ERR internal"), "panic not isolated: {r}");
    // run_many funnels the job panic to the submitter with its own
    // payload; dispatch surfaces that, not the failpoint's message.
    assert!(r.contains("pool task panicked"), "panic message lost: {r}");

    // Same connection still serves; the poisoned run was purged, so a
    // retry recomputes and succeeds (the @1 trigger is spent).
    assert_eq!(c.ask("PING"), "PONG");
    let retry = c.ask("PCC g C-2");
    assert!(retry.starts_with("OK "), "retry after panic failed: {retry}");

    // Other connections never noticed.
    let mut c2 = Wire::connect(&addr).unwrap();
    assert!(c2.ask("QUERY g 5").starts_with("OK "));
    let m = c2.ask("METRICS");
    assert!(m.contains("panics=1"), "panic not metered: {m}");
    assert!(m.contains("err/PCC=1"), "error not metered per verb: {m}");

    faults::clear();
    drop((c, c2));
    stop(&shutdown, handle);
}

/// A panicking verb must degrade HEALTH, not just METRICS.
#[test]
fn health_degrades_on_panics() {
    let _g = quiesce();
    let state = ServerState::new(1);
    assert!(ask(&state, "GEN g path:32").starts_with("OK "));
    faults::configure("pool.job=panic@1").unwrap();
    assert!(ask(&state, "SHARD g 2").starts_with("OK "));
    let r = ask(&state, "PCC g C-2");
    assert!(r.starts_with("ERR internal"), "{r}");
    faults::clear();
    let h = ask(&state, "HEALTH");
    assert!(h.contains("degraded"), "HEALTH ignored a recent panic: {h}");
}

// -------------------------------------------- deadlines and timeouts

/// ACCEPTANCE: a heavy verb over its `CONTOUR_DEADLINE_MS` budget
/// returns `ERR deadline` between passes instead of running away.
#[test]
fn over_budget_cc_returns_err_deadline() {
    let _g = quiesce();
    let state = ServerState::new(1).with_timeouts(0, 0, 1);
    assert!(ask(&state, "GEN g er:400000:800000").starts_with("OK 400000 "));
    let r = ask(&state, "CC g C-2");
    assert!(r.starts_with("ERR deadline exceeded after 1ms budget"), "{r}");
    let m = ask(&state, "METRICS");
    assert!(m.contains("deadlines=1"), "deadline not metered: {m}");
    // Light verbs carry no deadline and still work.
    assert_eq!(ask(&state, "PING"), "PONG");
}

/// Idle connections are closed gracefully (BYE, then EOF) after the
/// configured `CONTOUR_IDLE_MS` budget — the old hard-coded 5 s cutoff
/// is gone.
#[test]
fn idle_timeout_closes_with_bye() {
    let _g = quiesce();
    let state = Arc::new(ServerState::new(1).with_timeouts(150, 0, 0));
    let (addr, shutdown, handle) = spawn_server(state);
    let mut c = Wire::connect(&addr).unwrap();
    assert_eq!(c.read_line().unwrap(), "BYE", "idle close must announce itself");
    assert_eq!(c.read_line().unwrap(), "", "EOF after BYE");
    drop(c);
    stop(&shutdown, handle);
}

/// Graceful drain: on shutdown an idle connection gets BYE before the
/// socket closes, and the listener thread exits cleanly.
#[test]
fn shutdown_drains_with_bye() {
    let _g = quiesce();
    let state = Arc::new(ServerState::new(1));
    let (addr, shutdown, handle) = spawn_server(state);
    let mut c = Wire::connect(&addr).unwrap();
    assert_eq!(c.ask("PING"), "PONG");
    shutdown.store(true, Ordering::Relaxed);
    assert_eq!(c.read_line().unwrap(), "BYE", "drain must announce itself");
    drop(c);
    handle.join().unwrap().unwrap();
}

/// WATCH pushes ticks from the server side, so an idle budget shorter
/// than the tick interval must not kill the stream mid-WATCH — and the
/// connection is still usable afterwards.
#[test]
fn watch_survives_idle_gaps_between_ticks() {
    let _g = quiesce();
    let state = Arc::new(ServerState::new(1).with_timeouts(250, 0, 0));
    let (addr, shutdown, handle) = spawn_server(state);
    let mut c = Wire::connect(&addr).unwrap();
    assert_eq!(c.ask("WATCH 3 400"), "OK 3 400");
    for i in 0..3 {
        let tick = c.read_line().unwrap();
        assert!(!tick.is_empty() && tick != "BYE", "tick {i} lost to idle close: {tick:?}");
    }
    assert_eq!(c.read_line().unwrap(), "DONE");
    assert_eq!(c.ask("PING"), "PONG", "connection dead after WATCH");
    drop(c);
    stop(&shutdown, handle);
}

// ------------------------------------------------- connection chaos

/// An injected `conn.write` drop severs the connection between request
/// and reply — the client sees a clean close, the server keeps serving
/// new connections.
#[test]
fn dropped_reply_severs_only_that_connection() {
    let _g = quiesce();
    let state = Arc::new(ServerState::new(1));
    let (addr, shutdown, handle) = spawn_server(state);
    let mut c = Wire::connect(&addr).unwrap();
    assert_eq!(c.ask("PING"), "PONG");

    faults::configure("conn.write=drop@1").unwrap();
    let r = c.try_ask("PING").unwrap_or_default();
    assert_eq!(r, "", "reply should have been dropped: {r:?}");
    drop(c);

    let mut c2 = Wire::connect(&addr).unwrap();
    assert_eq!(c2.ask("PING"), "PONG", "server stopped answering after a dropped reply");
    faults::clear();
    drop(c2);
    stop(&shutdown, handle);
}

/// Hostile binary frames on a live upgraded socket: every malformed
/// input ends in a clean close (or a clean ERR) — never a panic, never
/// a hang — and the server keeps answering fresh connections.
#[test]
fn hostile_binary_input_never_hangs_or_kills_the_server() {
    let _g = quiesce();
    let state = Arc::new(ServerState::new(1));
    let (addr, shutdown, handle) = spawn_server(state);

    fn frame(magic: &[u8; 2], ver: u8, op: u8, id: u32, len: u32, payload: &[u8]) -> Vec<u8> {
        let mut b = Vec::with_capacity(12 + payload.len());
        b.extend_from_slice(magic);
        b.push(ver);
        b.push(op);
        b.extend_from_slice(&id.to_le_bytes());
        b.extend_from_slice(&len.to_le_bytes());
        b.extend_from_slice(payload);
        b
    }
    let cases: Vec<(&str, Vec<u8>)> = vec![
        ("bad magic", frame(b"XX", 2, 1, 1, 0, &[])),
        ("bad version", frame(b"CP", 9, 1, 1, 0, &[])),
        ("oversize length", frame(b"CP", 2, 1, 1, protocol::MAX_FRAME + 1, &[])),
        ("unknown opcode", frame(b"CP", 2, 0xEE, 1, 2, &[0, 0])),
        ("truncated header", vec![b'C', b'P', 2, 1, 7]),
        ("truncated payload", frame(b"CP", 2, 1, 1, 64, &[1, 2, 3])),
        ("args length overflow", frame(b"CP", 2, 1, 1, 2, &[255, 255])),
        ("garbage flood", vec![0xA5; 4096]),
    ];
    for (name, bytes) in &cases {
        let s = TcpStream::connect(&addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut w = s.try_clone().unwrap();
        w.write_all(b"HELLO 2\n").unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert_eq!(line.trim_end(), "OK v2", "{name}: upgrade failed");
        // The server may close before consuming everything we send.
        let _ = w.write_all(bytes);
        let _ = s.shutdown(Shutdown::Write);
        let mut buf = [0u8; 512];
        loop {
            match r.read(&mut buf) {
                Ok(0) => break, // clean close
                Ok(_) => {}     // a reply frame before the close is fine
                Err(e) => panic!("{name}: server hung instead of closing: {e}"),
            }
        }
        // The malformed connection took nothing else down.
        let mut probe = Wire::connect(&addr).unwrap();
        assert_eq!(probe.ask("PING"), "PONG", "{name}: server died");
    }

    // Line transport: a client vanishing mid-payload is a clean close.
    let mut c = Wire::connect(&addr).unwrap();
    c.w.write_all(b"UPLOAD u 3\n0 1\n").unwrap();
    drop(c);
    let mut probe = Wire::connect(&addr).unwrap();
    assert_eq!(probe.ask("PING"), "PONG", "mid-payload disconnect killed the server");
    drop(probe);
    stop(&shutdown, handle);
}

// --------------------------------------------------- the FAULTS verb

/// The test-gated FAULTS verb: refuse when disabled (pinned in
/// tests/serving.rs), and with `CONTOUR_FAULTS_VERB=1` list, arm, and
/// clear schedules at runtime.
#[test]
fn faults_verb_round_trip() {
    let _g = quiesce();
    std::env::set_var("CONTOUR_FAULTS_VERB", "1");
    let state = ServerState::new(1);
    assert_eq!(ask(&state, "FAULTS"), "OK 0");
    assert_eq!(ask(&state, "FAULTS SET wal.append=err@5"), "OK armed 1");
    // Lifetime injected counts survive CLEAR (and other tests in this
    // process), so only the armed-point half of the line is exact.
    let listing = ask(&state, "FAULTS");
    assert!(listing.starts_with("OK 1 wal.append err@5 hits=0 injected="), "{listing}");
    assert!(ask(&state, "FAULTS SET nope").starts_with("ERR "));
    assert_eq!(ask(&state, "FAULTS CLEAR"), "OK cleared");
    assert_eq!(ask(&state, "FAULTS"), "OK 0");
    std::env::remove_var("CONTOUR_FAULTS_VERB");
    faults::clear();
}

// ------------------------------------------------------ env-driven soak

/// CI chaos entry point: run a mixed workload under the schedule in
/// `CONTOUR_FAULTS` (or a broad default), tolerating injected errors
/// and dropped connections, then clear the faults and prove the server
/// still answers correctly. Tallies go to stderr for the CI artifact.
#[test]
fn soak_under_env_schedule_recovers() {
    let _g = quiesce();
    let schedule = std::env::var("CONTOUR_FAULTS").unwrap_or_else(|_| {
        "wal.append=err@p0.05;wal.fsync=err@p0.05;pool.job=panic@p0.02;conn.write=drop@p0.05"
            .to_string()
    });
    faults::configure(&schedule).unwrap();
    eprintln!("[chaos-soak] schedule: {schedule}");

    let dir = fresh_dir("soak");
    let wal = dir.join("s.wal");
    let state = Arc::new(ServerState::new(2));
    let (addr, shutdown, handle) = spawn_server(state);

    let (mut errs, mut drops) = (0u32, 0u32);
    let mut conn: Option<Wire> = None;
    for i in 0..160u32 {
        let op = match i % 9 {
            0 => "GEN g er:800:1500".to_string(),
            1 => "CC g C-2".to_string(),
            2 => format!("QUERY g {}", (i * 37) % 800),
            3 => "SHARD g 2".to_string(),
            4 => "PCC g C-2".to_string(),
            5 => format!("STREAM s 64 {}", wal.display()),
            6 => format!("SADD s {} {}", i % 64, (i + 1) % 64),
            // Delete the pair the preceding SADD added. If that SADD's
            // append was faulted (or a delete is re-tried after one),
            // the edge isn't live and this ERRs — tallied, tolerated.
            7 => format!("SDEL s {} {}", (i - 1) % 64, i % 64),
            _ => "SEPOCH s".to_string(),
        };
        if conn.is_none() {
            match Wire::connect(&addr) {
                Ok(c) => conn = Some(c),
                Err(_) => {
                    drops += 1;
                    continue;
                }
            }
        }
        let c = conn.as_mut().expect("connection ensured above");
        match c.try_ask(&op) {
            Ok(r) if r.is_empty() => {
                // Dropped reply: the connection is gone, reconnect.
                drops += 1;
                conn = None;
            }
            Ok(r) => {
                if r.starts_with("ERR") {
                    errs += 1;
                }
            }
            Err(_) => {
                drops += 1;
                conn = None;
            }
        }
    }
    drop(conn);

    // Faults off: the server must answer, correctly, on fresh state.
    faults::clear();
    let mut c = None;
    for _ in 0..10 {
        if let Ok(mut w) = Wire::connect(&addr) {
            if matches!(w.try_ask("PING").as_deref(), Ok("PONG")) {
                c = Some(w);
                break;
            }
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    let mut c = c.expect("server unreachable after faults cleared");
    assert!(c.ask("GEN h path:40").starts_with("OK 40 "));
    assert!(c.ask("CC h C-2").starts_with("OK 1 "));
    assert_eq!(c.ask("QUERY h 7 C-2"), "OK 0");
    let metrics = c.ask("METRICS");

    eprintln!("[chaos-soak] err_replies={errs} dropped_conns={drops}");
    for (point, count) in faults::injected_counts() {
        eprintln!("[chaos-soak] injected {point}={count}");
    }
    eprintln!("[chaos-soak] final {metrics}");
    drop(c);
    stop(&shutdown, handle);
}
