//! Exact-frontier stress tests on worst-case-diameter graphs.
//!
//! The exact engine's whole point (ROADMAP "smarter frontier
//! activation") is the high-diameter case: label propagation crosses
//! chunk borders for many passes, which forced the chunk engine into
//! periodic O(m) backstop sweeps. With the vertex→chunk activation map
//! those sweeps are gone — so on a long path we pin, per run (via the
//! `RunResult::frontier` stats, immune to other tests' runs in this
//! process):
//!
//! * **zero** forced full sweeps after startup (in fact zero, period:
//!   the initial pass is just the dirty set starting full),
//! * pass count staying O(log d) — asserted against a generous
//!   `4·log2(d) + 16` as well as against the chunk-mode engine's own
//!   pass count, so an accidental regression to wave-like O(d)
//!   propagation fails loudly,
//! * settled chunks actually being skipped (the star component below
//!   occupies its own leading chunks and quiesces within two passes),
//! * labels bit-identical to the full-sweep engine.

use contour::cc::contour::{Contour, FrontierMode};
use contour::cc::{self, Algorithm};
use contour::graph::{Csr, EdgeList};
use contour::util::Xoshiro256;
use contour::VId;

/// Star (ids `0..star`, settles in ~2 passes, fills its own leading
/// chunks of the sorted edge list) plus a long path over ids
/// `star..star+path` visited in a seeded random order (so the canonical
/// sorted edge order is uncorrelated with path adjacency and no single
/// in-order sweep collapses it — worst-case diameter stays worst-case).
fn star_plus_scrambled_path(star: usize, path: usize, seed: u64) -> Csr {
    let n = star + path;
    let mut e = EdgeList::with_capacity(n, n);
    for i in 1..star {
        e.push(0, i as VId);
    }
    let mut order: Vec<VId> = (star as VId..n as VId).collect();
    let mut rng = Xoshiro256::new(seed);
    rng.shuffle(&mut order);
    for w in order.windows(2) {
        e.push(w[0], w[1]);
    }
    e.into_csr()
}

#[test]
fn exact_engine_is_logarithmic_with_zero_forced_sweeps_on_paths() {
    let star = 5_000usize;
    let path = 30_000usize;
    let log2_d = (path as f64).log2().ceil() as usize;
    for seed in [3u64, 11] {
        let g = star_plus_scrambled_path(star, path, seed);
        let want = Contour::c2().with_frontier_mode(FrontierMode::Off).run(&g);
        assert_eq!(cc::num_components(&want), 2);
        for threads in [1usize, 4] {
            let exact = Contour::c2()
                .with_threads(threads)
                .with_frontier_mode(FrontierMode::Exact)
                .run_with_stats(&g);
            assert_eq!(exact.labels, want, "exact labels diverge (threads={threads})");
            // The tentpole claim: no backstop sweeps, ever — the dirty
            // set alone concludes convergence.
            assert_eq!(
                exact.frontier.full_sweeps, 0,
                "exact engine forced a full sweep (threads={threads})"
            );
            assert_eq!(exact.frontier.exact_passes as usize, exact.iterations);
            // The star settles within the first couple of passes; its
            // pure chunks must be skipped for the rest of the run.
            assert!(
                exact.frontier.skipped_chunks > 0,
                "no chunk ever skipped (threads={threads})"
            );
            assert!(exact.frontier.activations > 0);
            // O(log d): generous 4x + slack over the pointer-doubling
            // bound; a regression to O(d) wave propagation would be
            // thousands of passes.
            assert!(
                exact.iterations <= 4 * log2_d + 16,
                "exact needed {} passes on d={path} (bound {})",
                exact.iterations,
                4 * log2_d + 16
            );
            // And it must not blow up relative to the chunk engine it
            // replaces (chunk counts its backstop sweeps as passes too).
            let chunk = Contour::c2()
                .with_threads(threads)
                .with_frontier_mode(FrontierMode::Chunk)
                .run_with_stats(&g);
            assert_eq!(chunk.labels, want);
            assert!(chunk.frontier.full_sweeps >= 1, "chunk engine must backstop-sweep");
            assert!(
                exact.iterations <= 2 * chunk.iterations + 8,
                "exact {} passes vs chunk {} (threads={threads})",
                exact.iterations,
                chunk.iterations
            );
        }
    }
}

#[test]
fn exact_engine_handles_high_order_operators_on_paths() {
    // C-m (h = 1024) and the schedule variants lean hardest on
    // chain-interior stores — exactly the stores whose activations the
    // membership map must not miss. A long path makes any missed
    // activation show up as an under-merged component.
    let g = star_plus_scrambled_path(2_000, 12_000, 7);
    let want = cc::ground_truth(&g);
    for alg in [Contour::cm(), Contour::c11mm(), Contour::c1m1m()] {
        for threads in [1usize, 4] {
            let r = alg
                .clone()
                .with_threads(threads)
                .with_frontier_mode(FrontierMode::Exact)
                .run_with_stats(&g);
            assert_eq!(r.labels, want, "{} exact diverges (threads={threads})", alg.name());
            assert_eq!(r.frontier.full_sweeps, 0);
        }
    }
}

#[test]
fn exact_engine_sync_variant_on_paths() {
    // Sync + exact: the shadow-copy engine skips clean chunks too. The
    // pass count must stay within the same logarithmic ballpark (the
    // sync pass reads a stale array, so give it double room).
    let g = star_plus_scrambled_path(2_000, 12_000, 19);
    let log2_d = (12_000f64).log2().ceil() as usize;
    let want = Contour::csyn().with_frontier_mode(FrontierMode::Off).run(&g);
    for threads in [1usize, 4] {
        let r = Contour::csyn()
            .with_threads(threads)
            .with_frontier_mode(FrontierMode::Exact)
            .run_with_stats(&g);
        assert_eq!(r.labels, want, "sync exact diverges (threads={threads})");
        assert_eq!(r.frontier.full_sweeps, 0);
        assert!(r.frontier.skipped_chunks > 0, "sync exact never skipped (threads={threads})");
        assert!(
            r.iterations <= 8 * log2_d + 16,
            "sync exact needed {} passes (bound {})",
            r.iterations,
            8 * log2_d + 16
        );
    }
}

#[test]
fn exact_engine_concurrent_runs_do_not_interfere() {
    // Per-run dirty grids and membership indexes racing through the
    // shared worker pool (the server shape): every run must stay
    // bit-identical and sweep-free.
    let g = star_plus_scrambled_path(1_500, 8_000, 23);
    let want = Contour::c2().with_frontier_mode(FrontierMode::Off).run(&g);
    std::thread::scope(|s| {
        for _ in 0..4 {
            let g = &g;
            let want = &want;
            s.spawn(move || {
                for _ in 0..2 {
                    let r = Contour::c2()
                        .with_frontier_mode(FrontierMode::Exact)
                        .run_with_stats(g);
                    assert_eq!(&r.labels, want);
                    assert_eq!(r.frontier.full_sweeps, 0);
                }
            });
        }
    });
}
