//! Property-based tests over randomly generated graphs (the image has no
//! `proptest`, so this file carries a miniature property-test driver:
//! seeded case generation, a fixed case budget, and failing-seed
//! reporting — rerun any failure with its printed seed).

use contour::cc::contour::FrontierMode;
use contour::cc::{self, contour::Contour, Algorithm};
use contour::coordinator::{algorithm_by_name, ALGORITHM_NAMES};
use contour::graph::{gen, Csr, EdgeList};
use contour::util::Xoshiro256;
use contour::VId;

/// Mini property-test driver: runs `prop` on `cases` random seeds,
/// reporting every failing seed before panicking.
fn check_property<F: Fn(u64) -> Result<(), String>>(name: &str, cases: u64, prop: F) {
    let mut failures = Vec::new();
    for seed in 0..cases {
        if let Err(msg) = prop(seed) {
            failures.push(format!("seed {seed}: {msg}"));
            if failures.len() >= 3 {
                break;
            }
        }
    }
    assert!(failures.is_empty(), "property {name} failed:\n{}", failures.join("\n"));
}

/// Random graph with size/topology drawn from the seed: mixes sparse and
/// dense, connected and fragmented, plus degenerate corner cases.
fn random_graph(seed: u64) -> Csr {
    let mut rng = Xoshiro256::new(seed.wrapping_mul(0x9E37_79B9));
    match seed % 7 {
        0 => gen::erdos_renyi(1 + rng.below(800) as usize, rng.below(1_200) as usize, seed),
        1 => gen::barabasi_albert(2 + rng.below(700) as usize, 1 + rng.below(5) as usize, seed),
        2 => gen::rmat(6 + (seed % 5) as u32, 100 + rng.below(4_000) as usize,
                       gen::RmatKind::Graph500, seed),
        3 => gen::component_soup(1 + rng.below(12) as usize, 2 + rng.below(50) as usize, seed),
        4 => gen::kmer_chains(1 + rng.below(15) as usize, 2 + rng.below(60) as usize, seed),
        5 => {
            // Degenerate families: empty, singleton, no-edge, tiny.
            match seed % 4 {
                0 => EdgeList::new(1),
                1 => EdgeList::new(17),
                2 => gen::path(2),
                _ => gen::complete(3),
            }
        }
        _ => gen::delaunay(3 + rng.below(600) as usize, seed),
    }
    .into_csr()
    .shuffled_edges(seed)
}

/// INVARIANT: all 15 algorithms produce the identical min-id labelling.
#[test]
fn prop_all_algorithms_agree() {
    check_property("all_algorithms_agree", 60, |seed| {
        let g = random_graph(seed);
        let want = cc::ground_truth(&g);
        for &name in ALGORITHM_NAMES {
            let got = algorithm_by_name(name, 0).unwrap().run(&g);
            if got != want {
                return Err(format!("{name} diverges on n={} m={}", g.n, g.m()));
            }
        }
        Ok(())
    });
}

/// INVARIANT: labels are idempotent under re-running (a converged
/// labelling is a fixed point) and canonicalize is idempotent.
#[test]
fn prop_fixed_point_and_canonical_idempotent() {
    check_property("fixed_point", 40, |seed| {
        let g = random_graph(seed);
        let labels = Contour::c2().run(&g);
        let again = Contour::c2().run(&g);
        if labels != again {
            return Err("rerun changed labels".into());
        }
        let c1 = cc::canonicalize(&labels);
        let c2 = cc::canonicalize(&c1);
        if c1 != c2 {
            return Err("canonicalize not idempotent".into());
        }
        Ok(())
    });
}

/// INVARIANT (Theorem 1): synchronous MM^2 converges within
/// ceil(log_1.5(d_max)) + 1 iterations (+1 detection pass).
#[test]
fn prop_theorem1_bound() {
    check_property("theorem1_bound", 30, |seed| {
        let g = random_graph(seed);
        if g.m() == 0 {
            return Ok(());
        }
        let s = contour::graph::stats::stats(&g);
        let d = s.pseudo_diameter.max(1) as f64;
        let bound = d.log(1.5).ceil() as usize + 2; // +1 detection pass
        // Theorem 1 is about the full-sweep engine (every edge, every
        // iteration); pin it so the bound stays meaningful under any
        // CONTOUR_FRONTIER the suite runs with.
        let r = Contour::csyn().with_frontier_mode(FrontierMode::Off).run_with_stats(&g);
        if r.iterations > bound {
            return Err(format!(
                "sync C-2 took {} iters > bound {bound} (diam {})",
                r.iterations, s.pseudo_diameter
            ));
        }
        Ok(())
    });
}

/// INVARIANT: component count equals the number of label roots, and
/// every label is a component minimum.
#[test]
fn prop_label_structure() {
    check_property("label_structure", 40, |seed| {
        let g = random_graph(seed);
        let labels = Contour::c11mm().run(&g);
        for (v, &l) in labels.iter().enumerate() {
            if l > v as VId {
                return Err(format!("label {l} above vertex {v}"));
            }
            if labels[l as usize] != l {
                return Err(format!("label {l} is not a root"));
            }
        }
        let viols = cc::verify::check_labels(&g, &labels);
        if !viols.is_empty() {
            return Err(format!("{viols:?}"));
        }
        Ok(())
    });
}

/// INVARIANT: edge-order shuffling never changes the partition (only
/// the iteration count may differ).
#[test]
fn prop_edge_order_invariance() {
    check_property("edge_order_invariance", 30, |seed| {
        let g = random_graph(seed);
        let a = Contour::c2().run(&g);
        let g2 = g.clone().shuffled_edges(seed ^ 0xDEAD);
        let b = Contour::c2().run(&g2);
        if a != b {
            return Err("shuffle changed the partition".into());
        }
        Ok(())
    });
}

/// INVARIANT: thread count never changes the result (races affect
/// schedules, not outcomes — §III-B.3's correctness claim).
#[test]
fn prop_thread_count_invariance() {
    check_property("thread_invariance", 25, |seed| {
        let g = random_graph(seed | 1); // skip the heaviest seeds
        let want = Contour::c2().with_threads(1).run(&g);
        for t in [2usize, 4, 8] {
            let got = Contour::c2().with_threads(t).run(&g);
            if got != want {
                return Err(format!("threads={t} diverges"));
            }
        }
        Ok(())
    });
}

/// INVARIANT: generator determinism — same seed, same graph; and CSR
/// canonical form (sorted unique oriented edges, symmetric adjacency).
#[test]
fn prop_generator_and_csr_invariants() {
    check_property("generator_csr", 50, |seed| {
        let a = random_graph(seed);
        let b = random_graph(seed);
        if a.src != b.src || a.dst != b.dst {
            return Err("generator not deterministic".into());
        }
        // Oriented + unique.
        let mut seen = std::collections::HashSet::new();
        for (u, v) in a.edges() {
            if u >= v {
                return Err(format!("edge ({u},{v}) not oriented"));
            }
            if !seen.insert((u, v)) {
                return Err(format!("duplicate edge ({u},{v})"));
            }
        }
        // Degree sum == 2m.
        let total: usize = (0..a.n).map(|v| a.degree(v as VId)).sum();
        if total != 2 * a.m() {
            return Err("degree sum != 2m".into());
        }
        Ok(())
    });
}

/// METAMORPHIC INVARIANT: relabeling vertices by a random permutation
/// and running Contour on the relabeled graph yields — after mapping
/// the labels back — the same partition as running on the original.
/// This catches id-order dependence (e.g. an activation map or chunk
/// grid that accidentally keys off vertex magnitude) that equivalence
/// tests on a single labeling can never see. Exercises every frontier
/// engine: the exact map is the newest way to get this wrong.
#[test]
fn prop_vertex_permutation_invariance() {
    check_property("vertex_permutation_invariance", 18, |seed| {
        let g = random_graph(seed);
        let mut rng = Xoshiro256::new(seed ^ 0x51CA_B00D);
        let mut perm: Vec<VId> = (0..g.n as VId).collect();
        rng.shuffle(&mut perm);
        let mut pe = EdgeList::with_capacity(g.n, g.m());
        for (u, v) in g.edges() {
            pe.push(perm[u as usize], perm[v as usize]);
        }
        let pg = pe.into_csr().shuffled_edges(seed ^ 0x7E77);
        for mode in [FrontierMode::Off, FrontierMode::Chunk, FrontierMode::Exact] {
            let base = Contour::c2().with_frontier_mode(mode).run(&g);
            let permuted = Contour::c2().with_frontier_mode(mode).run(&pg);
            // Map the permuted labels back into the original vertex
            // order; the values live in permuted id space, which
            // same_partition's canonicalization washes out.
            let back: Vec<VId> = (0..g.n).map(|v| permuted[perm[v] as usize]).collect();
            if !cc::same_partition(&base, &back) {
                return Err(format!(
                    "partition changed under relabeling (frontier={}, n={}, m={})",
                    mode.as_str(),
                    g.n,
                    g.m()
                ));
            }
        }
        Ok(())
    });
}

/// METAMORPHIC INVARIANT: duplicating random edges, flipping
/// orientations, sprinkling self-loops and reshuffling the edge order
/// never changes the labelling — the canonicalization pipeline plus the
/// engine must be insensitive to how the same graph is spelled.
#[test]
fn prop_edge_duplication_and_shuffle_invariance() {
    check_property("edge_duplication_invariance", 18, |seed| {
        let g = random_graph(seed);
        let mut rng = Xoshiro256::new(seed ^ 0xD0_D0);
        let mut pairs: Vec<(VId, VId)> = g.edges().collect();
        // Duplicate ~half the edges, some flipped; add a few self-loops.
        for i in 0..pairs.len() {
            if rng.below(2) == 0 {
                let (u, v) = pairs[i];
                pairs.push(if rng.below(2) == 0 { (v, u) } else { (u, v) });
            }
        }
        for _ in 0..4usize.min(g.n) {
            let v = rng.below(g.n as u64) as VId;
            pairs.push((v, v));
        }
        let noisy = EdgeList::from_pairs(g.n, &pairs)
            .into_csr()
            .shuffled_edges(seed ^ 0xBEE5);
        for mode in [FrontierMode::Off, FrontierMode::Chunk, FrontierMode::Exact] {
            let a = Contour::c2().with_frontier_mode(mode).run(&g);
            let b = Contour::c2().with_frontier_mode(mode).run(&noisy);
            if a != b {
                return Err(format!(
                    "duplication/shuffle changed labels (frontier={}, n={}, m={})",
                    mode.as_str(),
                    g.n,
                    g.m()
                ));
            }
        }
        Ok(())
    });
}

/// INVARIANT: the distributed simulator computes the same partition as
/// the shared-memory algorithms (it runs the real algorithm).
#[test]
fn prop_distsim_iterations_match_sync() {
    use contour::distsim::{simulate, CostModel, DistAlgorithm};
    check_property("distsim_supersteps", 15, |seed| {
        let g = random_graph(seed);
        if g.m() == 0 {
            return Ok(());
        }
        let r = simulate(&g, 4, DistAlgorithm::Contour { hops: 2 }, CostModel::default());
        // The simulator models synchronous full sweeps; compare against
        // the same engine whatever CONTOUR_FRONTIER the suite runs with.
        let sync = Contour::csyn()
            .with_early_check(false)
            .with_frontier_mode(FrontierMode::Off)
            .run_with_stats(&g);
        // Same synchronous schedule => same superstep count (±1 for the
        // detection pass accounting).
        if r.supersteps.abs_diff(sync.iterations) > 1 {
            return Err(format!("distsim {} vs sync {}", r.supersteps, sync.iterations));
        }
        Ok(())
    });
}
