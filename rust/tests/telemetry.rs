//! Continuous-telemetry acceptance tests: ring sampling (monotone
//! timestamps, exact counter deltas under concurrent load), the
//! OpenMetrics exposition (structure + one family per METRICS entry),
//! sorted-stable METRICS rendering, HEALTH state transitions, WATCH
//! streaming on both transports, the sampler thread, the HTTP scrape
//! endpoint, and (feature-gated) per-run memory accounting.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use contour::obs::TimeSeries;
use contour::server::{
    protocol, serve_listener, serve_prom_listener, telemetry, ServerState, Session,
};
use contour::VId;

fn no_body() -> anyhow::Result<String> {
    anyhow::bail!("no extra payload expected")
}

fn ask(state: &ServerState, line: &str) -> String {
    Session::new(state).handle(line, no_body).unwrap_or_else(|| "BYE".into())
}

fn spawn_server(state: Arc<ServerState>) -> (String, Arc<AtomicBool>, std::thread::JoinHandle<anyhow::Result<()>>) {
    let shutdown = Arc::new(AtomicBool::new(false));
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("local_addr").to_string();
    let sd = Arc::clone(&shutdown);
    let handle = std::thread::spawn(move || serve_listener(listener, state, sd));
    (addr, shutdown, handle)
}

struct LineWire {
    r: BufReader<TcpStream>,
    w: BufWriter<TcpStream>,
}

impl LineWire {
    fn connect(addr: &str) -> Self {
        let s = TcpStream::connect(addr).expect("connect");
        Self { r: BufReader::new(s.try_clone().unwrap()), w: BufWriter::new(s) }
    }

    fn send(&mut self, msg: &str) {
        self.w.write_all(msg.as_bytes()).unwrap();
        self.w.write_all(b"\n").unwrap();
        self.w.flush().unwrap();
    }

    fn read_line(&mut self) -> String {
        let mut reply = String::new();
        self.r.read_line(&mut reply).unwrap();
        reply.trim_end().to_string()
    }

    fn ask(&mut self, msg: &str) -> String {
        self.send(msg);
        self.read_line()
    }

    /// Ask a length-prefixed multi-line verb (PROM): `OK <n>` then
    /// exactly n body lines.
    fn ask_multi(&mut self, msg: &str) -> String {
        self.send(msg);
        let head = self.read_line();
        let n: usize = head
            .strip_prefix("OK ")
            .unwrap_or_else(|| panic!("{msg} -> {head:?}"))
            .parse()
            .unwrap();
        let mut lines = Vec::with_capacity(n);
        for _ in 0..n {
            lines.push(self.read_line());
        }
        lines.join("\n")
    }
}

struct BinWire {
    r: BufReader<TcpStream>,
    w: BufWriter<TcpStream>,
}

impl BinWire {
    fn connect(addr: &str) -> Self {
        let s = TcpStream::connect(addr).expect("connect");
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut w = BufWriter::new(s);
        w.write_all(b"HELLO 2\n").unwrap();
        w.flush().unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert_eq!(line.trim_end(), "OK v2", "HELLO 2 negotiation failed");
        Self { r, w }
    }

    fn send(&mut self, id: u32, verb: &str, args: &str, extra: &[VId]) {
        let b = protocol::encode_request(id, verb, args, extra).unwrap();
        self.w.write_all(&b).unwrap();
    }

    fn recv(&mut self) -> protocol::ReplyFrame {
        protocol::read_reply(&mut self.r).unwrap().expect("server closed mid-stream")
    }
}

/// Grab `key=<value>` out of a space-separated reply.
fn field(reply: &str, key: &str) -> String {
    reply
        .split_whitespace()
        .find_map(|t| t.strip_prefix(key))
        .unwrap_or_else(|| panic!("{key} missing in {reply:?}"))
        .to_string()
}

// ------------------------------------------------------ ring sampling

/// Acceptance: ring samples keep monotone timestamps and exact counter
/// deltas while request traffic and sample pushes race each other.
#[test]
fn ring_sampling_monotone_with_exact_deltas_under_load() {
    let state = ServerState::new(1);
    telemetry::sample_into_ring(&state);
    std::thread::scope(|s| {
        for _ in 0..4 {
            s.spawn(|| {
                for _ in 0..50 {
                    assert_eq!(ask(&state, "PING"), "PONG");
                }
            });
        }
        s.spawn(|| {
            for _ in 0..20 {
                telemetry::sample_into_ring(&state);
                std::thread::sleep(Duration::from_millis(1));
            }
        });
    });
    telemetry::sample_into_ring(&state);

    let samples = state.ring.samples();
    assert!(samples.len() >= 3, "only {} samples retained", samples.len());
    let i = state.ring.index_of("requests").expect("requests in the ring schema");
    for w in samples.windows(2) {
        assert!(w[1].ts_ms >= w[0].ts_ms, "timestamps went backwards");
        assert!(w[1].values[i] >= w[0].values[i], "requests counter not monotone");
    }
    // First sample preceded all traffic, last followed it: the delta is
    // exactly the 4x50 PINGs (this state saw no other requests).
    let (first, last) = (samples.first().unwrap(), samples.last().unwrap());
    assert_eq!(first.values[i], 0);
    assert_eq!(TimeSeries::delta(first, last, i), 200);
}

// -------------------------------------------------- METRICS rendering

/// Satellite: METRICS renders in stable sorted key order.
#[test]
fn metrics_keys_are_sorted_and_stable() {
    let state = ServerState::new(1);
    assert!(ask(&state, "GEN g path:8").starts_with("OK"));
    assert!(ask(&state, "CC g C-2").starts_with("OK"));
    assert!(ask(&state, "CC nosuch C-2").starts_with("ERR"));

    let keys_of = |m: &str| -> Vec<String> {
        m.strip_prefix("OK ")
            .unwrap()
            .split_whitespace()
            .map(|t| t.split('=').next().unwrap().to_string())
            .collect()
    };
    let k1 = keys_of(&ask(&state, "METRICS"));
    for w in k1.windows(2) {
        assert!(w[0] < w[1], "METRICS keys out of order: {:?} before {:?}", w[0], w[1]);
    }
    // Stable across calls: same keys, same order (values move).
    assert_eq!(k1, keys_of(&ask(&state, "METRICS")), "key order drifted between calls");
    for want in ["requests", "lat/CC", "err/CC", "uptime_ms", "pool_workers"] {
        assert!(k1.iter().any(|k| k == want), "{want} missing from METRICS: {k1:?}");
    }
}

// ----------------------------------------------- OpenMetrics / PROM

/// Replicate the server's wire-key → exposition-name derivation.
fn prom_name(key: &str) -> String {
    let mut s = String::from("contour_");
    for c in key.chars() {
        s.push(if c.is_ascii_alphanumeric() { c } else { '_' });
    }
    s
}

/// Acceptance: the PROM body is well-formed OpenMetrics text and every
/// METRICS entry — plain counters/gauges, `lat/*` summaries, `err/*`
/// counters, `cache/*` pairs — has a corresponding exposition line.
#[test]
fn prom_exposition_covers_every_metrics_entry() {
    let state = ServerState::new(1);
    assert!(ask(&state, "GEN g path:32").starts_with("OK"));
    assert!(ask(&state, "CC g C-2").starts_with("OK"));
    assert!(ask(&state, "CC nosuch C-2").starts_with("ERR"));
    assert!(ask(&state, "SHARD g 2").starts_with("OK"));
    assert!(ask(&state, "PCC g C-2").starts_with("OK"));

    // METRICS first: by exposition time its key set can only have
    // grown (lat/METRICS lands after METRICS itself renders).
    let metrics = ask(&state, "METRICS").strip_prefix("OK ").unwrap().to_string();
    let reply = ask(&state, "PROM");
    let mut lines = reply.lines();
    let head = lines.next().unwrap();
    let n: usize = head.strip_prefix("OK ").expect("PROM header").parse().unwrap();
    let body: Vec<&str> = lines.collect();
    assert_eq!(body.len(), n, "line-count prefix disagrees with the body");
    assert_eq!(*body.last().unwrap(), "# EOF");

    // Structural validity: every line is `# TYPE <name> <kind>`, the
    // terminator, or `<name>[{labels}] <numeric value>` under a
    // declared family; family declarations arrive in sorted order.
    let mut families: Vec<String> = Vec::new();
    for l in &body[..n - 1] {
        if let Some(decl) = l.strip_prefix("# TYPE ") {
            let mut f = decl.split(' ');
            let name = f.next().unwrap().to_string();
            let kind = f.next().unwrap();
            assert!(matches!(kind, "counter" | "gauge" | "summary"), "{l}");
            if let Some(prev) = families.last() {
                assert!(*prev < name, "families out of order: {prev} before {name}");
            }
            families.push(name);
        } else {
            let (name_part, value) = l.rsplit_once(' ').unwrap_or_else(|| panic!("{l:?}"));
            assert!(value.parse::<f64>().is_ok(), "non-numeric sample value: {l}");
            let base = name_part.split('{').next().unwrap();
            let fam = base.strip_suffix("_sum").or_else(|| base.strip_suffix("_count"));
            let fam = fam.unwrap_or(base);
            assert!(
                families.iter().any(|f| f == fam || f == base),
                "sample line outside any declared family: {l}"
            );
        }
    }
    assert!(families.contains(&"contour_requests_total".to_string()), "{families:?}");
    assert!(families.contains(&"contour_uptime_ms".to_string()), "{families:?}");
    assert!(families.contains(&"contour_verb_latency_ns".to_string()), "{families:?}");
    // No sampler ran: the ring gauge reads 0 and no rate gauges exist.
    assert!(body.contains(&"contour_ring_samples 0"), "{reply}");
    assert!(!reply.contains("contour_rate_qps"), "rate gauges without a ring window");

    // Coverage: every METRICS key projects into the exposition.
    for tok in metrics.split_whitespace() {
        let key = tok.split('=').next().unwrap();
        let want = if let Some(verb) = key.strip_prefix("lat/") {
            format!("contour_verb_latency_ns{{verb=\"{verb}\",quantile=\"0.5\"}}")
        } else if let Some(verb) = key.strip_prefix("err/") {
            format!("contour_verb_errors_total{{verb=\"{verb}\"}}")
        } else if let Some(name) = key.strip_prefix("cache/") {
            format!("contour_cache_hits{{name=\"{name}\"}}")
        } else {
            prom_name(key)
        };
        assert!(
            body.iter().any(|l| l.starts_with(&want)),
            "METRICS key {key} has no exposition line (wanted prefix {want})"
        );
    }
}

// -------------------------------------------------------------- HEALTH

/// Acceptance: HEALTH reads ready on a fresh server and degrades, then
/// overloads, as the windowed busy rate is forced over its thresholds
/// (heavy cap 0 BUSYs every heavy verb).
#[test]
fn health_transitions_ready_degraded_overloaded() {
    let fresh = ServerState::new(1);
    let r = ask(&fresh, "HEALTH");
    assert!(r.starts_with("OK ready "), "{r}");

    // Drain mode: heavy_sat pins at 1.0 (degraded on its own) and every
    // GEN is a BUSY reply, so the busy fraction is under our control.
    let state = ServerState::new(1).with_admission(64, 0);
    for _ in 0..20 {
        assert_eq!(ask(&state, "PING"), "PONG");
    }
    for _ in 0..2 {
        assert!(ask(&state, "GEN g path:4").starts_with("ERR busy:"));
    }
    // 2 BUSY over ~23 requests: past degraded (0.05), short of 0.5.
    let r = ask(&state, "HEALTH");
    assert!(r.starts_with("OK degraded "), "{r}");
    let busy: f64 = field(&r, "busy_frac=").parse().unwrap();
    assert!((0.05..0.5).contains(&busy), "{r}");
    // No sampler pushed anything: the lifetime fallback served this.
    assert_eq!(field(&r, "samples="), "0", "{r}");

    for _ in 0..40 {
        assert!(ask(&state, "GEN g path:4").starts_with("ERR busy:"));
    }
    let r = ask(&state, "HEALTH");
    assert!(r.starts_with("OK overloaded "), "{r}");
    let busy: f64 = field(&r, "busy_frac=").parse().unwrap();
    assert!(busy >= 0.5, "{r}");
}

// --------------------------------------------------------------- WATCH

/// Acceptance: WATCH on the line transport streams its header, one TICK
/// line per interval with monotone timestamps and live request deltas,
/// then DONE — and the session keeps serving afterwards.
#[test]
fn watch_streams_ticks_on_the_line_transport() {
    let state = Arc::new(ServerState::new(1));
    let (addr, shutdown, handle) = spawn_server(Arc::clone(&state));

    // Background traffic so the tick deltas have something to report.
    let stop = Arc::new(AtomicBool::new(false));
    let pinger = {
        let (addr, stop) = (addr.clone(), Arc::clone(&stop));
        std::thread::spawn(move || {
            let mut w = LineWire::connect(&addr);
            while !stop.load(Ordering::Relaxed) {
                assert_eq!(w.ask("PING"), "PONG");
            }
            assert_eq!(w.ask("QUIT"), "BYE");
        })
    };

    let mut w = LineWire::connect(&addr);
    w.send("WATCH 3 25");
    assert_eq!(w.read_line(), "OK 3 25");
    let mut t_prev = 0u64;
    let mut req_sum = 0u64;
    for i in 0..3u64 {
        let tick = w.read_line();
        assert!(tick.starts_with(&format!("TICK {i} ")), "{tick}");
        let t_ms: u64 = field(&tick, "t_ms=").parse().unwrap();
        assert!(t_ms >= t_prev, "{tick}");
        t_prev = t_ms;
        assert!(field(&tick, "dt_ms=").parse::<u64>().unwrap() >= 1, "{tick}");
        req_sum += field(&tick, "requests=").parse::<u64>().unwrap();
        assert!(tick.contains(" qps="), "{tick}");
    }
    assert_eq!(w.read_line(), "DONE");
    stop.store(true, Ordering::Relaxed);
    pinger.join().unwrap();
    assert!(req_sum >= 1, "ticks never saw the background traffic");
    assert_eq!(w.ask("PING"), "PONG");
    assert_eq!(w.ask("QUIT"), "BYE");

    shutdown.store(true, Ordering::Relaxed);
    handle.join().unwrap().unwrap();
}

/// Acceptance: WATCH over binary v2 pushes one OK frame per tick (all
/// carrying the request id) plus a terminal DONE frame, while another
/// pipelined request interleaves on the same connection.
#[test]
fn watch_streams_frames_on_the_binary_transport() {
    let state = Arc::new(ServerState::new(1));
    let (addr, shutdown, handle) = spawn_server(Arc::clone(&state));

    let mut bin = BinWire::connect(&addr);
    bin.send(42, "WATCH", "3 10", &[]);
    bin.send(7, "PING", "", &[]);
    bin.w.flush().unwrap();

    let mut ticks = 0u64;
    let mut pong = false;
    loop {
        let f = bin.recv();
        if f.id == 7 {
            assert_eq!((f.status, f.text().as_str()), (protocol::STATUS_OK, "PONG"));
            pong = true;
            continue;
        }
        assert_eq!(f.id, 42, "unexpected request id in WATCH stream");
        assert_eq!(f.status, protocol::STATUS_OK, "{}", f.text());
        if f.text() == "DONE" {
            break;
        }
        assert!(f.text().starts_with(&format!("TICK {ticks} ")), "{}", f.text());
        ticks += 1;
    }
    assert_eq!(ticks, 3, "tick frames before DONE");
    assert!(pong, "pipelined PING never answered during the WATCH stream");

    bin.send(9, "QUIT", "", &[]);
    bin.w.flush().unwrap();
    let f = bin.recv();
    assert_eq!((f.id, f.status), (9, protocol::STATUS_BYE));
    assert!(protocol::read_reply(&mut bin.r).unwrap().is_none(), "frames after BYE");

    shutdown.store(true, Ordering::Relaxed);
    handle.join().unwrap().unwrap();
}

// ------------------------------------------------------ sampler thread

/// Acceptance: `serve_listener` runs the sampler at the configured
/// interval, HEALTH switches to its windowed (ring-backed) signals, and
/// PROM grows the ring-derived rate gauges.
#[test]
fn sampler_thread_fills_the_ring() {
    let state = Arc::new(ServerState::new(1).with_sample_interval(10));
    let (addr, shutdown, handle) = spawn_server(Arc::clone(&state));

    let mut w = LineWire::connect(&addr);
    for _ in 0..10 {
        assert_eq!(w.ask("PING"), "PONG");
    }
    std::thread::sleep(Duration::from_millis(150));

    assert!(state.ring.len() >= 3, "sampler pushed only {} samples", state.ring.len());
    for pair in state.ring.samples().windows(2) {
        assert!(pair[1].ts_ms >= pair[0].ts_ms, "sampler timestamps not monotone");
    }
    let h = w.ask("HEALTH");
    assert!(h.starts_with("OK "), "{h}");
    let n: usize = field(&h, "samples=").parse().unwrap();
    assert!(n >= 2, "HEALTH still on the lifetime fallback: {h}");
    let p = w.ask_multi("PROM");
    assert!(p.contains("contour_ring_samples "), "{p}");
    assert!(p.contains("contour_rate_qps "), "{p}");
    assert!(p.contains("contour_busy_fraction "), "{p}");
    assert_eq!(w.ask("QUIT"), "BYE");

    shutdown.store(true, Ordering::Relaxed);
    handle.join().unwrap().unwrap();
}

// ------------------------------------------------- HTTP scrape endpoint

/// The `--prom-addr` endpoint: any HTTP request gets a 200 with the
/// OpenMetrics exposition, an exact Content-Length, and a close.
#[test]
fn http_scrape_endpoint_serves_openmetrics() {
    let state = Arc::new(ServerState::new(1));
    assert_eq!(ask(&state, "PING"), "PONG");

    let shutdown = Arc::new(AtomicBool::new(false));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let (st, sd) = (Arc::clone(&state), Arc::clone(&shutdown));
    let handle = std::thread::spawn(move || serve_prom_listener(listener, st, sd));

    for _ in 0..2 {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"GET /metrics HTTP/1.1\r\nHost: test\r\nAccept: */*\r\n\r\n").unwrap();
        let mut buf = String::new();
        s.read_to_string(&mut buf).unwrap();
        assert!(buf.starts_with("HTTP/1.1 200 OK\r\n"), "{buf}");
        assert!(buf.contains("Content-Type: application/openmetrics-text"), "{buf}");
        let (head, body) = buf.split_once("\r\n\r\n").unwrap();
        let cl: usize =
            head.lines().find_map(|l| l.strip_prefix("Content-Length: ")).unwrap().parse().unwrap();
        assert_eq!(body.len(), cl, "Content-Length disagrees with the body");
        assert!(body.contains("contour_requests_total "), "{body}");
        assert!(body.trim_end().ends_with("# EOF"), "{body}");
    }

    shutdown.store(true, Ordering::Relaxed);
    handle.join().unwrap().unwrap();
}

// ----------------------------------------------------- memory accounting

/// Acceptance (alloc-track builds): a Contour run reports a nonzero
/// heap peak that reconciles with its working-set arrays — at least the
/// labels array, at most a small multiple of it. Other tests allocate
/// concurrently in this process, so the upper bounds stay loose.
#[cfg(feature = "alloc-track")]
#[test]
fn contour_run_reports_heap_peak_reconciling_with_labels() {
    use contour::cc::{contour::Contour, Algorithm, RunContext};

    let n = 1usize << 21;
    let g = contour::graph::gen::path(n).into_csr();
    let r = Contour::c2().run_ctx(&g, &RunContext::default());
    let m = r.mem.expect("alloc-track builds must report MemStats");
    let labels_bytes = (n * std::mem::size_of::<VId>()) as u64;
    assert!(m.peak_bytes >= labels_bytes, "peak {} < labels array {labels_bytes}", m.peak_bytes);
    assert!(m.peak_bytes <= 16 * labels_bytes, "peak {} implausibly large", m.peak_bytes);
    assert!(m.allocs > 0 && m.frees > 0, "{m:?}");
    // The returned labels vec is still live when the scope closes.
    assert!(m.net_bytes >= labels_bytes as i64 / 2, "net {} vs labels {labels_bytes}", m.net_bytes);
    assert!(m.net_bytes <= 16 * labels_bytes as i64, "net {} implausibly large", m.net_bytes);
}

/// Default builds carry no accounting: `RunResult::mem` stays `None`
/// and the allocator counters read zero.
#[cfg(not(feature = "alloc-track"))]
#[test]
fn mem_accounting_absent_without_the_feature() {
    use contour::cc::{contour::Contour, Algorithm, RunContext};

    assert!(!contour::obs::alloc::enabled());
    assert_eq!(contour::obs::alloc::current_bytes(), 0);
    assert_eq!(contour::obs::alloc::totals(), (0, 0, 0, 0));
    let g = contour::graph::gen::path(64).into_csr();
    let r = Contour::c2().run_ctx(&g, &RunContext::default());
    assert!(r.mem.is_none(), "mem stats in a no-feature build");
}
