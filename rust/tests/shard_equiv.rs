//! Sharded-connectivity equivalence matrix: sharded labels must be
//! component-equivalent (in fact: bit-identical, since both sides are
//! canonical min-vertex-id labellings) to single-shard Contour across
//! generators × shard counts × operator hops — plus a wire-level test
//! that two clients' `PCC` requests genuinely overlap in the pool.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use contour::cc::{self, contour::Contour, Algorithm};
use contour::graph::{gen, Csr};
use contour::server::{serve_listener, ServerState};
use contour::shard::{run_sharded, Balance, ShardedGraph};

fn generators() -> Vec<(&'static str, Csr)> {
    vec![
        ("rmat", gen::rmat(10, 4_000, gen::RmatKind::Graph500, 3).into_csr().shuffled_edges(1)),
        ("er", gen::erdos_renyi(1_500, 2_500, 5).into_csr().shuffled_edges(2)),
        ("soup", gen::component_soup(10, 80, 7).into_csr()),
        ("road", gen::road(30, 30, 9).into_csr().shuffled_edges(3)),
        ("path", gen::path(2_000).into_csr().shuffled_edges(4)),
    ]
}

/// The acceptance matrix: generators × shard counts {1,2,4,7} × hops
/// {1,2} × fence policies {vertices, edges}. Also pins the stronger
/// property that sharded labels are the *identical* canonical
/// labelling, and partition edge conservation.
#[test]
fn sharded_equivalent_to_single_shard_contour() {
    for (gname, g) in generators() {
        let want = cc::ground_truth(&g);
        for hops in [1usize, 2] {
            let alg = match hops {
                1 => Contour::c1(),
                _ => Contour::c2(),
            };
            // Single-shard Contour at these hops agrees with ground
            // truth (both canonical), so `want` stands in for it.
            assert_eq!(alg.run(&g), want, "{gname} single-shard h{hops}");
            for p in [1usize, 2, 4, 7] {
                for balance in [Balance::Vertices, Balance::Edges] {
                    let sg = ShardedGraph::partition_with(&g, p, balance);
                    assert_eq!(
                        sg.shards.iter().map(|s| s.graph.m()).sum::<usize>() + sg.boundary.len(),
                        g.m(),
                        "{gname} p={p} {balance:?}: edges lost in partitioning"
                    );
                    let r = run_sharded(&sg, &alg, 0);
                    assert!(
                        cc::same_partition(&r.labels, &want),
                        "{gname} p={p} h{hops} {balance:?}: not component-equivalent"
                    );
                    assert_eq!(
                        r.labels, want,
                        "{gname} p={p} h{hops} {balance:?}: not canonical min-id"
                    );
                }
            }
        }
    }
}

/// Sharded runs with a union-find local algorithm and with explicit
/// thread caps stay equivalent too.
#[test]
fn sharded_equivalence_is_algorithm_and_thread_agnostic() {
    let g = gen::rmat(11, 8_000, gen::RmatKind::Graph500, 13).into_csr().shuffled_edges(5);
    let want = cc::ground_truth(&g);
    let sg = ShardedGraph::partition(&g, 4);
    for threads in [1usize, 2, 0] {
        let r = run_sharded(&sg, &Contour::c2().with_threads(threads), threads);
        assert_eq!(r.labels, want, "threads={threads}");
    }
    let r = run_sharded(&sg, &contour::cc::unionfind::RemConcurrent::new(), 0);
    assert_eq!(r.labels, want, "union-find local algorithm");
}

fn ask(reader: &mut BufReader<TcpStream>, writer: &mut BufWriter<TcpStream>, msg: &str) -> String {
    writer.write_all(msg.as_bytes()).unwrap();
    writer.write_all(b"\n").unwrap();
    writer.flush().unwrap();
    let mut reply = String::new();
    reader.read_line(&mut reply).unwrap();
    reply.trim().to_string()
}

fn connect(addr: std::net::SocketAddr) -> (BufReader<TcpStream>, BufWriter<TcpStream>) {
    let stream = TcpStream::connect(addr).expect("connect");
    (BufReader::new(stream.try_clone().unwrap()), BufWriter::new(stream))
}

/// Two clients issue `PCC` on different graphs concurrently: both must
/// complete correctly, and the pool's in-flight high-water mark must
/// show ≥ 2 jobs overlapping (each sharded run alone submits one job
/// per shard; two sessions overlap on top of that — the old
/// single-job-slot pool could never exceed 1). With the shard-labels
/// cache, each graph computes once and the repeat requests are hits.
#[test]
fn concurrent_pcc_requests_overlap_in_the_pool() {
    let state = Arc::new(ServerState::new(0));
    let shutdown = Arc::new(AtomicBool::new(false));
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("local_addr");
    let s2 = Arc::clone(&state);
    let sd2 = Arc::clone(&shutdown);
    let server = std::thread::spawn(move || serve_listener(listener, s2, sd2));

    // Set up two independent sharded graphs over one admin connection.
    let (mut r0, mut w0) = connect(addr);
    assert!(ask(&mut r0, &mut w0, "GEN a er:4000:8000").starts_with("OK"));
    assert!(ask(&mut r0, &mut w0, "GEN b rmat:11:4").starts_with("OK"));
    assert!(ask(&mut r0, &mut w0, "SHARD a 4").starts_with("OK 4 "));
    assert!(ask(&mut r0, &mut w0, "SHARD b 4").starts_with("OK 4 "));
    let cc_a = ask(&mut r0, &mut w0, "CC a C-2");
    let cc_b = ask(&mut r0, &mut w0, "CC b C-2");

    // Two client threads hammer PCC on their own graph concurrently.
    let workers: Vec<_> = [("a", cc_a.clone()), ("b", cc_b.clone())]
        .into_iter()
        .map(|(name, cc_reply)| {
            std::thread::spawn(move || {
                let (mut r, mut w) = connect(addr);
                let want_comps = cc_reply.split_whitespace().nth(1).unwrap().to_string();
                for _ in 0..5 {
                    let reply = ask(&mut r, &mut w, &format!("PCC {name} C-2"));
                    assert!(reply.starts_with("OK "), "{reply}");
                    assert_eq!(
                        reply.split_whitespace().nth(1).unwrap(),
                        want_comps,
                        "PCC {name} disagrees with CC: {reply} vs {cc_reply}"
                    );
                }
                ask(&mut r, &mut w, "QUIT");
            })
        })
        .collect();
    for h in workers {
        h.join().unwrap();
    }

    // Each PCC submits its 4 shard jobs as one in-flight batch, so the
    // high-water mark is ≥ 2 deterministically (≥ 4, in fact), and with
    // two sessions racing the batches overlap on top of each other.
    let metrics = ask(&mut r0, &mut w0, "METRICS");
    let metric = |key: &str| -> u64 {
        metrics
            .split_whitespace()
            .find_map(|t| t.strip_prefix(key).and_then(|v| v.strip_prefix('=')))
            .unwrap_or_else(|| panic!("{key} in METRICS: {metrics}"))
            .parse()
            .unwrap()
    };
    assert!(metric("pool_max_inflight") >= 2, "no job overlap observed: {metrics}");
    // Stronger than batch accounting: task *bodies* ran concurrently.
    // Only assert when the pool actually has extra workers — on a
    // single-hardware-thread runner execution is legitimately serial.
    if metric("pool_workers") >= 2 {
        assert!(
            metric("pool_exec_peak") >= 2,
            "shard jobs never executed concurrently: {metrics}"
        );
    }
    // The shard-labels cache: each (graph, alg, p, balance) computed
    // exactly once; the other 4 requests per graph were hits.
    let pcc_runs: u64 = metrics
        .split_whitespace()
        .find_map(|t| t.strip_prefix("pcc_runs="))
        .expect("pcc_runs in METRICS")
        .parse()
        .unwrap();
    assert_eq!(pcc_runs, 2, "{metrics}");
    for name in ["a", "b"] {
        let kv = metrics
            .split_whitespace()
            .find_map(|t| t.strip_prefix(&format!("cache/shard/{name}=")))
            .unwrap_or_else(|| panic!("cache/shard/{name} in METRICS: {metrics}"));
        assert_eq!(kv, "4:1", "shard cache accounting for {name}: {metrics}");
    }
    assert_eq!(ask(&mut r0, &mut w0, "QUIT"), "BYE");

    shutdown.store(true, Ordering::Relaxed);
    server.join().unwrap().unwrap();
}
