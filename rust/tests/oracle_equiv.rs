//! Differential oracle harness: **every** `cc::Algorithm`
//! implementation — the six Contour variants under every frontier
//! engine, FastSV, Shiloach–Vishkin, both union-finds, ConnectIt,
//! label propagation, both BFS forms, and Afforest — must induce the
//! same component partition (up to label renaming) on a seeded
//! randomized generator matrix, sequential and parallel. ConnectIt and
//! Groute-style asynchronous CC lean on exactly this kind of
//! cross-algorithm matrix to trust precise activation; until now only
//! contour-vs-contour (`frontier_equiv`) and shard-vs-single
//! (`shard_equiv`) were pinned.
//!
//! The generator set — {rmat, er, road, path, soup, delaunay}:
//! power-law, uniform, mesh, worst-case diameter, many
//! components, planar — each stresses a different failure mode
//! (hub contention, scattered merges, border propagation, deep chains,
//! cross-component leaks, local structure).

use contour::cc::contour::FrontierMode;
use contour::cc::{self, Algorithm};
use contour::coordinator::{algorithm_by_name_with, ALGORITHM_NAMES};
use contour::graph::{gen, Csr};

/// The Contour variants of `ALGORITHM_NAMES` (the only algorithms with
/// a frontier engine to vary).
const CONTOUR_NAMES: &[&str] = &["C-1", "C-2", "C-m", "C-11mm", "C-1m1m", "C-Syn"];

const THREAD_COUNTS: [usize; 2] = [1, 4];

fn generators(seed: u64) -> Vec<(&'static str, Csr)> {
    vec![
        (
            "rmat",
            gen::rmat(11, 12_000, gen::RmatKind::Graph500, seed)
                .into_csr()
                .shuffled_edges(seed ^ 0xA1),
        ),
        ("er", gen::erdos_renyi(8_000, 15_000, seed).into_csr().shuffled_edges(seed ^ 0xA2)),
        ("road", gen::road(55, 55, seed).into_csr().shuffled_edges(seed ^ 0xA3)),
        ("path", gen::path(4_000).into_csr().shuffled_edges(seed ^ 0xA4)),
        ("soup", gen::component_soup(6, 40, seed).into_csr().shuffled_edges(seed ^ 0xA5)),
        ("delaunay", gen::delaunay(1_500, seed).into_csr().shuffled_edges(seed ^ 0xA6)),
    ]
}

/// Every algorithm × every generator × sequential and parallel, against
/// the BFS oracle. Partition equivalence is the contract; exact min-id
/// equality is asserted on top because every implementation here
/// canonicalizes (a representation bug would slip past `same_partition`
/// alone).
#[test]
fn oracle_every_algorithm_on_every_generator() {
    for (gname, g) in generators(1) {
        let truth = cc::ground_truth(&g);
        for &name in ALGORITHM_NAMES {
            for threads in THREAD_COUNTS {
                let labels = algorithm_by_name_with(name, threads, None).unwrap().run(&g);
                assert!(
                    cc::same_partition(&labels, &truth),
                    "{name} partitions {gname} wrongly (threads={threads}, n={}, m={})",
                    g.n,
                    g.m()
                );
                assert_eq!(
                    labels, truth,
                    "{name} labels not canonical min-id on {gname} (threads={threads})"
                );
            }
        }
    }
}

/// The Contour frontier matrix: variants × generators × threads ×
/// {off, chunk, exact}. Labels must be **bit-identical** across
/// engines — the frontier only changes which chunks a pass touches.
#[test]
fn oracle_contour_frontier_matrix() {
    for seed in [3u64, 9] {
        for (gname, g) in generators(seed) {
            let truth = cc::ground_truth(&g);
            for &name in CONTOUR_NAMES {
                for threads in THREAD_COUNTS {
                    for mode in [FrontierMode::Off, FrontierMode::Chunk, FrontierMode::Exact] {
                        let labels = algorithm_by_name_with(name, threads, Some(mode))
                            .unwrap()
                            .run(&g);
                        assert_eq!(
                            labels,
                            truth,
                            "{name} diverges on {gname} (seed={seed}, threads={threads}, \
                             frontier={})",
                            mode.as_str()
                        );
                    }
                }
            }
        }
    }
}

/// Sanity on the matrix itself: the Contour names used above must stay
/// a subset of the factory registry (a renamed variant would silently
/// shrink the matrix).
#[test]
fn oracle_matrix_covers_known_names() {
    for &name in CONTOUR_NAMES {
        assert!(
            ALGORITHM_NAMES.contains(&name),
            "{name} not in ALGORITHM_NAMES — oracle matrix out of date"
        );
    }
    // And the factory rejects garbage rather than falling back.
    assert!(algorithm_by_name_with("C-3", 1, Some(FrontierMode::Exact)).is_err());
}
