//! Stress tests for the persistent worker pool behind `contour::par`:
//! the substrate every parallel pass in the crate now runs on. These
//! exercise the shapes the server produces in production — concurrent
//! sessions submitting passes at once, nested parallelism, thousands of
//! short passes reusing the same workers — and pin down that pooled
//! execution is bit-identical to sequential execution for every Contour
//! variant.

use std::sync::atomic::{AtomicU64, Ordering};

use contour::cc::contour::Contour;
use contour::cc::Algorithm;
use contour::graph::gen;
use contour::par;

#[test]
fn nested_parallel_passes_from_a_parallel_pass() {
    // Outer pass over disjoint ranges; each range runs its own inner
    // parallel pass. The inner calls must run inline (the outer pass
    // already owns the workers) and still cover every index once.
    let n = 1 << 17;
    let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
    par::par_for(n, 0, 1 << 12, |outer| {
        let base = outer.start;
        par::par_for(outer.len(), 0, 64, |inner| {
            for i in inner {
                hits[base + i].fetch_add(1, Ordering::Relaxed);
            }
        });
    });
    assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
}

#[test]
fn concurrent_sessions_share_one_pool() {
    // Several OS threads (the server's one-thread-per-connection model)
    // submit parallel passes concurrently; jobs run in flight together
    // on the multi-job pool and every session must get exact results.
    let sessions = 4;
    let rounds = 25;
    let n = 1 << 17;
    let want = (n as u64 - 1) * n as u64 / 2;
    std::thread::scope(|s| {
        for _ in 0..sessions {
            s.spawn(|| {
                for _ in 0..rounds {
                    let total = par::par_map_reduce(
                        n,
                        0,
                        par::AUTO_GRAIN,
                        || 0u64,
                        |acc, r| *acc += r.map(|i| i as u64).sum::<u64>(),
                        |a, b| a + b,
                    );
                    assert_eq!(total, want);
                }
            });
        }
    });
}

#[test]
fn pool_reused_across_a_thousand_tiny_passes() {
    // A C-2 run is a sequence of short passes; the server multiplies
    // that by requests. 1000 small passes must all hit the same pool
    // (job counter advances, no spawn-per-pass) and stay correct.
    let before = par::pool::stats().jobs;
    let n = 40_000; // above SEQ_CUTOFF at the adaptive bottom grain
    let want = (n as u64 - 1) * n as u64 / 2;
    for _ in 0..1000 {
        let total = par::par_map_reduce(
            n,
            0,
            par::AUTO_GRAIN,
            || 0u64,
            |acc, r| *acc += r.map(|i| i as u64).sum::<u64>(),
            |a, b| a + b,
        );
        assert_eq!(total, want);
    }
    if par::num_threads() > 1 && par::exec_mode() == par::ExecMode::Pooled {
        let after = par::pool::stats().jobs;
        assert!(after >= before + 1000, "pool jobs {before} -> {after}: passes bypassed the pool");
    }
}

#[test]
fn thousand_sticky_passes_keep_a_stable_chunk_to_worker_mapping() {
    // The execution-engine contract: a sticky pass over a stable chunk
    // grid lands each chunk block on the same worker every time (slot
    // jobs live on their home worker's queue and are excluded from
    // stealing), so a hot loop re-touches warm cache lines instead of
    // scattering. 1000 passes over one grid; the pool metrics must show
    // sticky placement never migrated, and every index must be covered
    // exactly once per pass.
    let before = par::pool::stats();
    let grid = par::Chunks::new(1 << 17, 1 << 12);
    let hits: Vec<AtomicU64> = (0..grid.len).map(|_| AtomicU64::new(0)).collect();
    let passes = 1000u64;
    for _ in 0..passes {
        par::par_for_sticky(grid, 0, |c, r| {
            assert_eq!(r, grid.range(c), "chunk ids must be grid-stable");
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
    }
    assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == passes));
    if par::num_threads() > 1 && par::exec_mode() == par::ExecMode::Pooled {
        let after = par::pool::stats();
        assert!(
            after.sticky_jobs >= before.sticky_jobs + passes,
            "sticky passes bypassed the pool: {} -> {}",
            before.sticky_jobs,
            after.sticky_jobs
        );
        // Stability, asserted via metrics: every sticky slot job in the
        // process ran on its home worker — the chunk→worker mapping
        // never moved across all 1000 passes.
        assert_eq!(
            after.sticky_away, before.sticky_away,
            "sticky slot jobs migrated off their home worker"
        );
        assert!(
            after.sticky_home >= before.sticky_home + passes,
            "home-worker executions did not advance: {} -> {}",
            before.sticky_home,
            after.sticky_home
        );
    }
}

#[test]
fn pooled_labels_bit_identical_to_single_thread_for_all_variants() {
    // Property pinned by the refactor: for every Contour variant the
    // pooled parallel run must produce exactly the label array the
    // threads=1 sequential run produces (both are canonical min-id
    // labellings, so full Vec equality is the right check).
    let graphs = vec![
        gen::rmat(12, 20_000, gen::RmatKind::Graph500, 7).into_csr(),
        gen::path(30_000).into_csr().shuffled_edges(11),
        gen::component_soup(12, 2_000, 5).into_csr(),
    ];
    for g in &graphs {
        for alg in Contour::all_variants() {
            let seq = alg.clone().with_threads(1).run(g);
            let pooled = alg.clone().with_threads(0).run(g);
            assert_eq!(seq, pooled, "{} diverges on n={} m={}", alg.name(), g.n, g.m());
        }
    }
}

#[test]
fn concurrent_contour_runs_share_the_pool() {
    // Whole algorithm runs (not just single passes) racing through the
    // pool from separate sessions, as CC requests do.
    let g = gen::rmat(12, 30_000, gen::RmatKind::Graph500, 3).into_csr();
    let want = Contour::c2().with_threads(1).run(&g);
    std::thread::scope(|s| {
        for _ in 0..4 {
            let g = &g;
            let want = &want;
            s.spawn(move || {
                for _ in 0..3 {
                    assert_eq!(&Contour::c2().run(g), want);
                }
            });
        }
    });
}
