//! Streaming-subsystem integration tests: streamed labels against
//! static Contour on the same final graph across graph families, WAL +
//! snapshot crash-recovery round trips, non-blocking concurrent
//! queries, and the server's STREAM* verbs end to end.

use std::sync::atomic::{AtomicBool, Ordering};

use contour::cc::{self, contour::Contour, Algorithm};
use contour::graph::{gen, Csr};
use contour::server::{ServerState, Session};
use contour::stream::{Snapshot, StreamingCc, Wal, WalRecord};
use contour::VId;

fn families() -> Vec<(&'static str, Csr)> {
    vec![
        ("path", gen::path(900).into_csr().shuffled_edges(1)),
        ("star", gen::star(700).into_csr().shuffled_edges(2)),
        ("rmat", gen::rmat(11, 9_000, gen::RmatKind::Graph500, 3).into_csr()),
        ("soup", gen::component_soup(15, 50, 4).into_csr().shuffled_edges(5)),
    ]
}

/// ACCEPTANCE: streamed labels equal static `Contour::c2()` labels
/// (min-vertex-id canonical form) on the same final graph, for every
/// family, at every intermediate epoch (vs. static run on the prefix).
#[test]
fn streamed_labels_match_static_contour_per_family() {
    for (name, g) in families() {
        let s = StreamingCc::new(g.n, 0);
        let edges: Vec<(VId, VId)> = g.edges().collect();
        let mut fed = 0usize;
        for chunk in edges.chunks(251) {
            s.add_edges(chunk).unwrap();
            fed += chunk.len();
            // Spot-check a prefix epoch halfway through the feed.
            if fed >= edges.len() / 2 && fed - chunk.len() < edges.len() / 2 {
                let snap = s.seal_epoch().unwrap();
                let prefix =
                    contour::graph::EdgeList::from_pairs(g.n, &edges[..fed]).into_csr();
                assert_eq!(
                    snap.labels,
                    Contour::c2().run(&prefix),
                    "{name}: prefix epoch diverges"
                );
            }
        }
        let fin = s.seal_epoch().unwrap();
        let want = Contour::c2().run(&g);
        assert_eq!(fin.labels, want, "{name}: final labels diverge from static C-2");
        assert_eq!(fin.num_components, cc::num_components(&want), "{name}");
        assert_eq!(fin.labels, cc::ground_truth(&g), "{name}: not min-id canonical");
    }
}

/// ACCEPTANCE: WAL + snapshot crash-recovery round trip reproduces the
/// static labelling — with the snapshot + WAL suffix, with the WAL
/// alone, and through a second-generation recovery.
#[test]
fn crash_recovery_round_trip() {
    let dir = std::env::temp_dir().join("contour_stream_recovery_test");
    std::fs::create_dir_all(&dir).unwrap();
    let wal = dir.join("g.wal");
    let snap = dir.join("g.snap");
    let _ = std::fs::remove_file(&wal);

    let g = gen::rmat(10, 4_000, gen::RmatKind::Graph500, 9).into_csr();
    let edges: Vec<(VId, VId)> = g.edges().collect();
    let half = edges.len() / 2;
    {
        let s = StreamingCc::open(g.n, 1, Some(wal.as_path())).unwrap();
        s.add_edges(&edges[..half]).unwrap();
        s.seal_epoch().unwrap();
        s.save_snapshot(&snap).unwrap();
        s.add_edges(&edges[half..]).unwrap();
        // "Crash": dropped with the second half only in the WAL.
    }
    let want = Contour::c2().run(&g);

    // Snapshot + WAL suffix.
    let r = StreamingCc::recover(Some(snap.as_path()), Some(wal.as_path()), 0).unwrap();
    assert_eq!(r.current().labels, want);
    assert_eq!(r.edges_ingested(), edges.len());
    assert!(r.epoch() >= 2, "recovery seals a fresh epoch");

    // WAL alone (full replay; recovery above appended its own seal —
    // harmless on replay).
    let r2 = StreamingCc::recover(None, Some(wal.as_path()), 0).unwrap();
    assert_eq!(r2.current().labels, want);

    // Recovered streams stay usable and durable: keep ingesting through
    // the re-attached WAL, then recover once more.
    r2.add_edges(&[(0, (g.n - 1) as VId)]).unwrap();
    let sealed = r2.seal_epoch().unwrap();
    drop(r2);
    let r3 = StreamingCc::recover(None, Some(wal.as_path()), 0).unwrap();
    assert_eq!(r3.current().labels, sealed.labels);
    assert!(r3.current().same_comp(0, (g.n - 1) as VId).unwrap());

    // The raw log really is the full edge history.
    let (wn, records) = Wal::replay(&wal).unwrap();
    assert_eq!(wn, g.n);
    let logged: usize = records
        .iter()
        .map(|rec| match rec {
            WalRecord::Edges(b) => b.len(),
            WalRecord::Deletes(_) | WalRecord::EpochSeal(_) => 0,
        })
        .sum();
    assert_eq!(logged, edges.len() + 1);
}

/// `open` with an existing WAL path is recovery-on-open.
#[test]
fn open_recovers_existing_wal() {
    let dir = std::env::temp_dir().join("contour_stream_open_test");
    std::fs::create_dir_all(&dir).unwrap();
    let wal = dir.join("reopen.wal");
    let _ = std::fs::remove_file(&wal);

    let g = gen::component_soup(6, 30, 2).into_csr();
    let edges: Vec<(VId, VId)> = g.edges().collect();
    {
        let s = StreamingCc::open(g.n, 1, Some(wal.as_path())).unwrap();
        s.add_edges(&edges).unwrap();
    }
    let s = StreamingCc::open(g.n, 1, Some(wal.as_path())).unwrap();
    assert_eq!(s.current().labels, Contour::c2().run(&g));
    // Mismatched universe is refused.
    assert!(StreamingCc::open(g.n + 5, 1, Some(wal.as_path())).is_err());
}

/// ACCEPTANCE: concurrent SQUERY-style reads never block on ingestion
/// batches — readers make continuous progress against immutable
/// snapshots while writers ingest and seal, and every positive
/// connectivity observation stays true in the final graph.
#[test]
fn concurrent_queries_during_ingestion() {
    let n = 40_000usize;
    let s = StreamingCc::new(n, 1);
    let done = AtomicBool::new(false);
    std::thread::scope(|sc| {
        let readers: Vec<_> = (0..4u64)
            .map(|r| {
                let s = &s;
                let done = &done;
                sc.spawn(move || {
                    let mut rng = contour::util::SplitMix64::new(77 + r);
                    let mut queries = 0u64;
                    let mut positives = Vec::new();
                    while !done.load(Ordering::Relaxed) {
                        let snap = s.current();
                        let u = (rng.next_u64() % n as u64) as VId;
                        let v = (rng.next_u64() % n as u64) as VId;
                        if snap.same_comp(u, v).unwrap() && u != v {
                            positives.push((u, v));
                        }
                        assert!(snap.comp_size(u).unwrap() >= 1);
                        queries += 1;
                    }
                    assert!(queries > 0, "reader starved");
                    positives
                })
            })
            .collect();
        std::thread::scope(|wc| {
            for t in 0..3usize {
                let s = &s;
                wc.spawn(move || {
                    let edges: Vec<(VId, VId)> = (t..n - 1)
                        .step_by(3)
                        .map(|i| (i as VId, (i + 1) as VId))
                        .collect();
                    for chunk in edges.chunks(512) {
                        s.add_edges(chunk).unwrap();
                    }
                });
            }
            let s = &s;
            wc.spawn(move || {
                for _ in 0..6 {
                    s.seal_epoch().unwrap();
                    std::thread::yield_now();
                }
            });
        });
        done.store(true, Ordering::Relaxed);
        let fin = s.seal_epoch().unwrap();
        assert!(fin.labels.iter().all(|&l| l == 0), "path must collapse to one component");
        for h in readers {
            for (u, v) in h.join().unwrap() {
                assert_eq!(fin.labels[u as usize], fin.labels[v as usize]);
            }
        }
    });
}

/// The server's streaming verbs, driven through a Session exactly like
/// a TCP client would.
#[test]
fn server_stream_verbs_end_to_end() {
    let dir = std::env::temp_dir().join("contour_server_stream_test");
    std::fs::create_dir_all(&dir).unwrap();
    let snap = dir.join("srv.snap");
    let wal = dir.join("srv.wal");
    let _ = std::fs::remove_file(&wal);

    let state = ServerState::new(1);
    let mut session = Session::new(&state);
    let mut ask = |line: String| session.handle(&line, || unreachable!()).unwrap();

    assert_eq!(ask(format!("STREAM st 6 {}", wal.display())), "OK 6 0");
    assert_eq!(ask("SADD st 0 1 2 3".into()), "OK 2 0");
    // Epoch 0 predates the batch.
    assert_eq!(ask("SQUERY st SAME 0 1".into()), "OK 0 0");
    assert_eq!(ask("SEPOCH st".into()), "OK 1 4");
    assert_eq!(ask("SQUERY st SAME 0 1".into()), "OK 1 1");
    assert_eq!(ask("SQUERY st SIZE 0".into()), "OK 2 1");
    assert_eq!(ask("SQUERY st COMPS".into()), "OK 4 1");
    assert_eq!(ask("SQUERY st LABEL 3".into()), "OK 2 1");
    // Time travel to the sealed-but-empty epoch 0.
    assert_eq!(ask("SQUERY st SAME 0 1 0".into()), "OK 0 0");
    assert_eq!(ask("SQUERY st COMPS 0".into()), "OK 6 0");
    assert!(ask("SQUERY st COMPS 99".into()).starts_with("ERR"));
    assert!(ask("SQUERY st SAME 0 9".into()).starts_with("ERR"));
    assert!(ask("SADD st 5".into()).starts_with("ERR"), "odd id count");
    assert!(ask("SADD st 0 42".into()).starts_with("ERR"), "out of range");

    // Durability verbs.
    assert_eq!(ask(format!("SSAVE st {}", snap.display())), "OK 1");
    assert!(ask(format!("SLOAD st {}", snap.display())).starts_with("ERR"), "name taken");
    // The live stream still owns its WAL: a second appender is refused.
    assert!(
        ask(format!("SLOAD st2 {} {}", snap.display(), wal.display())).starts_with("ERR"),
        "one WAL, one stream"
    );
    assert!(ask(format!("STREAM st3 6 {}", wal.display())).starts_with("ERR"));
    // Snapshot-only recovery is fine alongside the live stream. The
    // reply leads with the classic `n epoch`, then the recovery stats.
    let reply = ask(format!("SLOAD st2 {}", snap.display()));
    assert!(reply.starts_with("OK 6 "), "{reply}");
    assert!(reply.contains("snapshot="), "recovery stats missing: {reply}");
    let epoch = reply.split_whitespace().nth(2).unwrap();
    assert_eq!(ask("SQUERY st2 SAME 0 1".into()), format!("OK 1 {epoch}"));

    // LIST shows streams; DROP removes them.
    let list = ask("LIST".into());
    assert!(list.contains("stream/st:6:2"), "{list}");
    assert!(list.contains("stream/st2:6:2"), "{list}");
    assert_eq!(ask("DROP st2".into()), "OK");
    assert!(ask("SQUERY st2 COMPS".into()).starts_with("ERR"));

    // Metrics picked up the streaming counters.
    let metrics = ask("METRICS".into());
    assert!(metrics.contains("streams=2"), "{metrics}");
    assert!(metrics.contains("stream_edges=2"), "{metrics}");
    assert!(metrics.contains("stream_queries="), "{metrics}");

    // A numeric extra on STREAM caps the retained epoch history.
    assert_eq!(ask("STREAM hist 5 2".into()), "OK 5 0");
    assert_eq!(ask("SADD hist 0 1".into()), "OK 1 0");
    assert_eq!(ask("SEPOCH hist".into()), "OK 1 4");
    assert_eq!(ask("SEPOCH hist".into()), "OK 2 4");
    assert_eq!(ask("SEPOCH hist".into()), "OK 3 4");
    assert!(ask("SQUERY hist COMPS 1".into()).starts_with("ERR"), "epoch 1 evicted");
    assert_eq!(ask("SQUERY hist COMPS 3".into()), "OK 4 3");
}

/// SDEL-equivalent deletions through a Session: multiset semantics,
/// seal-boundary visibility, error paths, and the stream_deletes
/// counter.
#[test]
fn server_delete_verbs_end_to_end() {
    let state = ServerState::new(1);
    let mut session = Session::new(&state);
    let mut ask = |line: String| session.handle(&line, || unreachable!()).unwrap();

    assert_eq!(ask("STREAM d 6".into()), "OK 6 0");
    // (1,2) twice: parallel edges are a multiset.
    assert_eq!(ask("SADD d 0 1 1 2 1 2".into()), "OK 3 0");
    assert_eq!(ask("SEPOCH d".into()), "OK 1 4");
    assert_eq!(ask("SDEL d 1 2".into()), "OK 1 1");
    // One multiplicity survives: still connected after the seal.
    assert_eq!(ask("SEPOCH d".into()), "OK 2 4");
    assert_eq!(ask("SQUERY d SAME 1 2".into()), "OK 1 2");
    // Deletes normalize orientation exactly like inserts.
    assert_eq!(ask("SDEL d 2 1".into()), "OK 1 2");
    assert_eq!(ask("SEPOCH d".into()), "OK 3 5");
    assert_eq!(ask("SQUERY d SAME 1 2".into()), "OK 0 3");
    assert_eq!(ask("SQUERY d SAME 0 1".into()), "OK 1 3");
    // Old epochs keep their pre-delete view.
    assert_eq!(ask("SQUERY d SAME 1 2 2".into()), "OK 1 2");
    // A dead edge, an odd id list, out-of-range ids, a missing stream:
    // clean ERRs, none counted as deletions.
    assert!(ask("SDEL d 1 2".into()).starts_with("ERR"), "edge no longer live");
    assert!(ask("SDEL d 3".into()).starts_with("ERR"), "odd id count");
    assert!(ask("SDEL d 0 42".into()).starts_with("ERR"), "out of range");
    assert!(ask("SDEL nosuch 0 1".into()).starts_with("ERR"));
    let metrics = ask("METRICS".into());
    assert!(metrics.contains("stream_deletes=2"), "{metrics}");
}

/// ACCEPTANCE: deletions are durable. Interleaved insert/delete frames
/// replay from the WAL (with and without a snapshot seed), snapshots
/// carry the live-edge count through the v3 format, and snapshot-only
/// recovery — which has no multiset to check deletes against — refuses
/// them loudly instead of corrupting.
#[test]
fn deletes_survive_crash_recovery() {
    let dir = std::env::temp_dir().join("contour_stream_delete_recovery_test");
    std::fs::create_dir_all(&dir).unwrap();
    let wal = dir.join("del.wal");
    let snap = dir.join("del.snap");
    let _ = std::fs::remove_file(&wal);

    {
        let s = StreamingCc::open(10, 1, Some(wal.as_path())).unwrap();
        s.add_edges(&[(0, 1), (1, 2), (2, 3), (3, 4)]).unwrap();
        s.delete_edges(&[(1, 2)]).unwrap();
        s.seal_epoch().unwrap();
        s.save_snapshot(&snap).unwrap();
        assert_eq!(s.edges_live(), 3);
        s.add_edges(&[(1, 2), (5, 6)]).unwrap();
        s.delete_edges(&[(3, 4), (5, 6)]).unwrap();
        // "Crash" mid-epoch: the unsealed suffix holds both frame kinds.
    }
    let survivors = [(0, 1), (2, 3), (1, 2)];
    let want = Contour::c2().run(&contour::graph::EdgeList::from_pairs(10, &survivors).into_csr());

    // WAL alone: full replay rebuilds the surviving multiset.
    let r = StreamingCc::recover(None, Some(wal.as_path()), 1).unwrap();
    assert_eq!(r.current().labels, want);
    assert_eq!(r.edges_ingested(), 6);
    assert_eq!(r.edges_live(), 3);
    assert_eq!(r.edges_deleted(), 3);
    let info = r.recovery().unwrap();
    assert_eq!(info.deletes_replayed, 3);
    assert!(info.summary().contains("deletes=3"), "{}", info.summary());

    // Snapshot + WAL agrees (deletions force the full-log path: labels
    // with a deleted edge baked in cannot seed a merge-only union-find).
    let r2 = StreamingCc::recover(Some(snap.as_path()), Some(wal.as_path()), 1).unwrap();
    assert_eq!(r2.current().labels, want);
    assert_eq!(r2.edges_live(), 3);

    // Recovered streams keep deleting: retire a replayed edge and one
    // more recovery still matches a static recompute.
    r2.delete_edges(&[(2, 3)]).unwrap();
    let sealed = r2.seal_epoch().unwrap();
    drop(r2);
    let r2b = StreamingCc::recover(None, Some(wal.as_path()), 1).unwrap();
    assert_eq!(r2b.current().labels, sealed.labels);
    assert_eq!(r2b.edges_live(), 2);

    // Snapshot alone: the v3 live-edge count round-trips...
    let r3 = StreamingCc::recover(Some(snap.as_path()), None, 1).unwrap();
    assert_eq!(r3.edges_ingested(), 4);
    assert_eq!(r3.edges_live(), 3);
    // ...and with no multiset, pre-snapshot edges are not deletable.
    assert!(r3.delete_edges(&[(0, 1)]).is_err());
}

/// ACCEPTANCE: differential churn soak. A deterministic ≥300-op
/// interleaved insert/delete/seal/query schedule over two generator
/// families × threads {1, 4}, where every sealed epoch's labels are
/// bit-identical to a from-scratch static Contour C-2 run on the
/// surviving edge multiset — finishing with a kill mid-epoch that
/// leaves unsealed inserts *and* deletes in the WAL suffix, which
/// recovery must replay to the same answer.
#[test]
fn churn_soak_matches_static_contour() {
    for (gname, g) in [
        ("rmat", gen::rmat(10, 3_000, gen::RmatKind::Graph500, 21).into_csr()),
        ("er", gen::erdos_renyi(800, 2_000, 22).into_csr()),
    ] {
        for threads in [1usize, 4] {
            churn_soak(gname, &g, threads);
        }
    }
}

fn churn_soak(gname: &str, g: &Csr, threads: usize) {
    let tag = format!("{gname} t{threads}");
    let dir = std::env::temp_dir().join(format!("contour_churn_soak_{gname}_{threads}"));
    std::fs::create_dir_all(&dir).unwrap();
    let wal = dir.join("soak.wal");
    let _ = std::fs::remove_file(&wal);

    let edges: Vec<(VId, VId)> = g.edges().collect();
    let s = StreamingCc::open(g.n, threads, Some(wal.as_path())).unwrap();
    let mut rng = contour::util::SplitMix64::new(1_000 * threads as u64 + 7);
    // The oracle: a mirror of the surviving edge multiset, and the
    // labels of the last sealed epoch.
    let mut live: Vec<(VId, VId)> = Vec::new();
    let mut last_want: Vec<VId> = (0..g.n as VId).collect();
    let mut next = 0usize;

    let verify = |s: &StreamingCc, live: &[(VId, VId)], at: &str| -> Vec<VId> {
        let snap = s.seal_epoch().unwrap();
        let want = Contour::c2().run(&contour::graph::EdgeList::from_pairs(g.n, live).into_csr());
        assert_eq!(snap.labels, want, "{at}: sealed epoch {} diverges from static C-2", snap.epoch);
        assert_eq!(s.edges_live(), live.len(), "{at}: live-edge count drifted");
        want
    };

    for op in 0..300usize {
        match rng.next_u64() % 10 {
            // ~half the schedule feeds generator edges in uneven chunks.
            0..=4 if next < edges.len() => {
                let take = (edges.len() - next).min(11 + (rng.next_u64() as usize) % 43);
                let chunk = &edges[next..next + take];
                assert_eq!(s.add_edges(chunk).unwrap(), take, "{tag} op {op}");
                live.extend_from_slice(chunk);
                next += take;
            }
            // Deletes pick random live victims (multiset-correctly:
            // each victim leaves the mirror as it is accepted).
            5..=6 if !live.is_empty() => {
                let k = 1 + (rng.next_u64() as usize) % live.len().min(9);
                let mut batch = Vec::with_capacity(k);
                for _ in 0..k {
                    let i = (rng.next_u64() as usize) % live.len();
                    batch.push(live.swap_remove(i));
                }
                assert_eq!(s.delete_edges(&batch).unwrap(), k, "{tag} op {op}");
            }
            7 => last_want = verify(&s, &live, &format!("{tag} op {op}")),
            // Queries answer from the last sealed epoch, exactly.
            _ => {
                let u = (rng.next_u64() % g.n as u64) as VId;
                let v = (rng.next_u64() % g.n as u64) as VId;
                let snap = s.current();
                assert_eq!(
                    snap.same_comp(u, v).unwrap(),
                    last_want[u as usize] == last_want[v as usize],
                    "{tag} op {op}: query diverges from last sealed oracle"
                );
            }
        }
    }

    // Deterministic tail: flush the rest of the feed, force at least
    // one delete-aware seal, and check the re-contour path really ran.
    if next < edges.len() {
        s.add_edges(&edges[next..]).unwrap();
        live.extend_from_slice(&edges[next..]);
    }
    if live.is_empty() {
        s.add_edges(&edges[..1]).unwrap();
        live.push(edges[0]);
    }
    let victim = live.swap_remove(live.len() / 2);
    s.delete_edges(&[victim]).unwrap();
    verify(&s, &live, &format!("{tag} tail"));
    assert!(
        s.scoped_recontours() + s.full_recontours() >= 1,
        "{tag}: no delete-aware seal ran"
    );

    // Kill mid-epoch: unsealed inserts and deletes in the WAL suffix.
    s.add_edges(&[victim]).unwrap();
    live.push(victim);
    let k = 1 + live.len() / 8;
    let mut batch = Vec::with_capacity(k);
    for _ in 0..k {
        let i = (rng.next_u64() as usize) % live.len();
        batch.push(live.swap_remove(i));
    }
    s.delete_edges(&batch).unwrap();
    drop(s);

    let r = StreamingCc::recover(None, Some(wal.as_path()), threads).unwrap();
    let want = Contour::c2().run(&contour::graph::EdgeList::from_pairs(g.n, &live).into_csr());
    assert_eq!(r.current().labels, want, "{tag}: recovery diverges from static C-2");
    assert_eq!(r.edges_live(), live.len(), "{tag}: recovered live-edge count drifted");
    assert!(r.recovery().unwrap().deletes_replayed > 0, "{tag}: no deletes in the replayed log");
}

/// Snapshots on disk are validated, versioned artifacts.
#[test]
fn snapshot_files_round_trip_through_disk() {
    let dir = std::env::temp_dir().join("contour_stream_snapfile_test");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("roundtrip.snap");

    let g = gen::erdos_renyi(400, 700, 3).into_csr();
    let s = StreamingCc::new(g.n, 1);
    s.add_edges(&g.edges().collect::<Vec<_>>()).unwrap();
    s.seal_epoch().unwrap();
    s.save_snapshot(&p).unwrap();

    let loaded = Snapshot::load(&p).unwrap();
    assert_eq!(loaded.labels, Contour::c2().run(&g));
    assert_eq!(loaded.epoch, 1);

    // Recovery from the snapshot alone (no WAL) restores the state.
    let r = StreamingCc::recover(Some(p.as_path()), None, 1).unwrap();
    assert_eq!(r.current().labels, loaded.labels);
    assert_eq!(r.n(), g.n);
}
