//! Active-edge frontier equivalence matrix: both frontier engines
//! (chunk dirty-bits and the exact vertex→chunk activation map) must
//! produce labels **bit-identical** to the full-sweep engine for every
//! variant, on every generator class, sequential and parallel. All
//! engines converge to the canonical min-vertex-id labelling — the
//! frontier only changes which chunks each intermediate pass touches —
//! so full `Vec` equality is the right check, and any under-merge from
//! a mis-skipped chunk (or a missed activation) shows up as a hard
//! mismatch.
//!
//! The generator set spans the shapes that stress the frontiers
//! differently: low-diameter power-law (rmat — chunks settle fast, the
//! case the chunk frontier wins on), uniform random (er), mesh (road —
//! label propagation crosses chunk borders: the chunk engine's backstop
//! case and the exact map's reason to exist), and worst-case diameter
//! (path — see tests/frontier_exact.rs for the exact engine's pass
//! count and zero-sweep pins there).

use contour::cc::contour::{Contour, FrontierMode};
use contour::cc::Algorithm;
use contour::graph::{gen, Csr};

/// Generators sized above the parallel cutoff so the pooled sticky
/// substrate (not just the inline fallback) is exercised.
fn generators() -> Vec<(&'static str, Csr)> {
    vec![
        ("rmat", gen::rmat(13, 60_000, gen::RmatKind::Graph500, 3).into_csr().shuffled_edges(1)),
        ("er", gen::erdos_renyi(20_000, 40_000, 5).into_csr().shuffled_edges(2)),
        ("road", gen::road(100, 100, 9).into_csr().shuffled_edges(3)),
        ("path", gen::path(30_000).into_csr().shuffled_edges(4)),
    ]
}

#[test]
fn frontier_bit_identical_to_full_sweep_for_all_variants() {
    for (gname, g) in generators() {
        for alg in Contour::all_variants() {
            for threads in [1usize, 4] {
                let full = alg
                    .clone()
                    .with_threads(threads)
                    .with_frontier_mode(FrontierMode::Off)
                    .run(&g);
                for mode in [FrontierMode::Chunk, FrontierMode::Exact] {
                    let got = alg
                        .clone()
                        .with_threads(threads)
                        .with_frontier_mode(mode)
                        .run(&g);
                    assert_eq!(
                        got,
                        full,
                        "{} on {gname} (n={} m={}) threads={threads}: {} engine diverges",
                        alg.name(),
                        g.n,
                        g.m(),
                        mode.as_str()
                    );
                }
            }
        }
    }
}

#[test]
fn frontier_equivalence_holds_under_concurrent_runs() {
    // Frontier runs racing through the shared pool (the server shape):
    // per-run dirty grids and membership indexes must not interfere
    // across sessions, in either engine.
    let g = gen::rmat(12, 30_000, gen::RmatKind::Graph500, 7).into_csr().shuffled_edges(6);
    let want = Contour::c2().with_threads(1).with_frontier_mode(FrontierMode::Off).run(&g);
    std::thread::scope(|s| {
        for i in 0..4 {
            let g = &g;
            let want = &want;
            let mode = if i % 2 == 0 { FrontierMode::Chunk } else { FrontierMode::Exact };
            s.spawn(move || {
                for _ in 0..3 {
                    let got = Contour::c2().with_frontier_mode(mode).run(g);
                    assert_eq!(&got, want, "{} engine diverged concurrently", mode.as_str());
                }
            });
        }
    });
}

#[test]
fn frontier_skip_accounting_is_visible() {
    // The execution engine must actually skip settled chunks on a
    // low-diameter graph (otherwise "frontier mode" is a no-op) while
    // staying bit-identical.
    let g = gen::rmat(13, 120_000, gen::RmatKind::Graph500, 11).into_csr().shuffled_edges(8);
    let (_, s0) = contour::cc::contour::frontier_counters();
    let full = Contour::c2().with_frontier(false).run(&g);
    let frontier = Contour::c2().with_frontier(true).run(&g);
    assert_eq!(frontier, full);
    let (_, s1) = contour::cc::contour::frontier_counters();
    assert!(s1 > s0, "frontier mode never skipped a chunk");
}
