//! Active-edge frontier equivalence matrix: frontier-mode Contour must
//! produce labels **bit-identical** to the full-sweep engine for every
//! variant, on every generator class, sequential and parallel. Both
//! engines converge to the canonical min-vertex-id labelling — the
//! frontier only changes which chunks each intermediate pass touches —
//! so full `Vec` equality is the right check, and any under-merge from
//! a mis-skipped chunk shows up as a hard mismatch.
//!
//! The generator set spans the shapes that stress the frontier
//! differently: low-diameter power-law (rmat — chunks settle fast, the
//! case the frontier wins on), uniform random (er), mesh (road — label
//! propagation crosses chunk borders, exercising the periodic
//! full-sweep backstop), and worst-case diameter (path).

use contour::cc::contour::Contour;
use contour::cc::Algorithm;
use contour::graph::{gen, Csr};

/// Generators sized above the parallel cutoff so the pooled sticky
/// substrate (not just the inline fallback) is exercised.
fn generators() -> Vec<(&'static str, Csr)> {
    vec![
        ("rmat", gen::rmat(13, 60_000, gen::RmatKind::Graph500, 3).into_csr().shuffled_edges(1)),
        ("er", gen::erdos_renyi(20_000, 40_000, 5).into_csr().shuffled_edges(2)),
        ("road", gen::road(100, 100, 9).into_csr().shuffled_edges(3)),
        ("path", gen::path(30_000).into_csr().shuffled_edges(4)),
    ]
}

#[test]
fn frontier_bit_identical_to_full_sweep_for_all_variants() {
    for (gname, g) in generators() {
        for alg in Contour::all_variants() {
            for threads in [1usize, 4] {
                let full = alg.clone().with_threads(threads).with_frontier(false).run(&g);
                let frontier = alg.clone().with_threads(threads).with_frontier(true).run(&g);
                assert_eq!(
                    frontier,
                    full,
                    "{} on {gname} (n={} m={}) threads={threads}: frontier diverges",
                    alg.name(),
                    g.n,
                    g.m()
                );
            }
        }
    }
}

#[test]
fn frontier_equivalence_holds_under_concurrent_runs() {
    // Frontier runs racing through the shared pool (the server shape):
    // per-run dirty grids must not interfere across sessions.
    let g = gen::rmat(12, 30_000, gen::RmatKind::Graph500, 7).into_csr().shuffled_edges(6);
    let want = Contour::c2().with_threads(1).with_frontier(false).run(&g);
    std::thread::scope(|s| {
        for _ in 0..4 {
            let g = &g;
            let want = &want;
            s.spawn(move || {
                for _ in 0..3 {
                    let got = Contour::c2().with_frontier(true).run(g);
                    assert_eq!(&got, want);
                }
            });
        }
    });
}

#[test]
fn frontier_skip_accounting_is_visible() {
    // The execution engine must actually skip settled chunks on a
    // low-diameter graph (otherwise "frontier mode" is a no-op) while
    // staying bit-identical.
    let g = gen::rmat(13, 120_000, gen::RmatKind::Graph500, 11).into_csr().shuffled_edges(8);
    let (_, s0) = contour::cc::contour::frontier_counters();
    let full = Contour::c2().with_frontier(false).run(&g);
    let frontier = Contour::c2().with_frontier(true).run(&g);
    assert_eq!(frontier, full);
    let (_, s1) = contour::cc::contour::frontier_counters();
    assert!(s1 > s0, "frontier mode never skipped a chunk");
}
