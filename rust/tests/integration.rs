//! Cross-module integration tests: every algorithm against every graph
//! family, coordinator batches, io round trips through real files, and
//! the figure pipeline on a miniature corpus.

use contour::cc::contour::FrontierMode;
use contour::cc::{self, Algorithm};
use contour::coordinator::{
    algorithm_by_name, algorithm_by_name_with, auto_select, Coordinator, Job, ALGORITHM_NAMES,
};
use contour::graph::{gen, io, stats, Csr, EdgeList};

fn family() -> Vec<(String, Csr)> {
    vec![
        ("path".into(), gen::path(700).into_csr().shuffled_edges(1)),
        ("cycle".into(), gen::cycle(512).into_csr().shuffled_edges(2)),
        ("star".into(), gen::star(600).into_csr()),
        ("grid".into(), gen::grid(25, 25).into_csr().shuffled_edges(3)),
        ("tree".into(), gen::binary_tree(9).into_csr().shuffled_edges(4)),
        ("comb".into(), gen::comb(40, 12).into_csr().shuffled_edges(5)),
        ("soup".into(), gen::component_soup(12, 60, 6).into_csr()),
        ("er".into(), gen::erdos_renyi(2_000, 3_500, 7).into_csr()),
        ("ba".into(), gen::barabasi_albert(2_500, 4, 8).into_csr()),
        ("rmat".into(), gen::rmat(12, 30_000, gen::RmatKind::Graph500, 9).into_csr()),
        ("delaunay".into(), gen::delaunay(3_000, 10).into_csr().shuffled_edges(11)),
        ("kmer".into(), gen::kmer_chains(30, 80, 12).into_csr().shuffled_edges(13)),
        ("road".into(), gen::road(40, 40, 14).into_csr().shuffled_edges(15)),
    ]
}

/// The central correctness matrix: 15 algorithms x 13 graph families,
/// all validated against BFS ground truth via the verifier.
#[test]
fn every_algorithm_on_every_family() {
    for (gname, g) in family() {
        let truth = cc::ground_truth(&g);
        for &aname in ALGORITHM_NAMES {
            let alg = algorithm_by_name(aname, 0).unwrap();
            let labels = alg.run(&g);
            assert_eq!(labels, truth, "{aname} on {gname}");
        }
        cc::verify::assert_valid(&g, &truth, &format!("truth/{gname}"));
    }
}

/// Iteration-count shape from §IV-C, on the graph where it's starkest.
#[test]
fn iteration_shape_on_high_diameter() {
    let g = gen::road(80, 80, 1).into_csr().shuffled_edges(7);
    // Full-sweep engine pinned: the §IV-C iteration shape is a claim
    // about full sweeps, and must hold under any CONTOUR_FRONTIER the
    // suite runs with (the exact-engine CI job sets it process-wide).
    let iters = |name: &str| {
        algorithm_by_name_with(name, 0, Some(FrontierMode::Off))
            .unwrap()
            .run_with_stats(&g)
            .iterations
    };
    let (i1, i2, im, isyn, ifsv) =
        (iters("C-1"), iters("C-2"), iters("C-m"), iters("C-Syn"), iters("FastSV"));
    assert!(im <= i2 && i2 <= i1, "C-m {im} <= C-2 {i2} <= C-1 {i1}");
    assert!(i1 >= 3 * i2, "C-1 {i1} must blow up vs C-2 {i2} on road graphs");
    assert!(isyn + 2 >= i2, "sync C-Syn {isyn} should not beat async C-2 {i2} by much");
    assert!(ifsv > 1, "FastSV iterates ({ifsv})");
    assert_eq!(iters("ConnectIt"), 1);
}

/// Coordinator batch over a mixed job set with the auto policy.
#[test]
fn coordinator_batch_mixed() {
    let graphs = family();
    let lookup = |name: &str| graphs.iter().find(|(n, _)| n == name).map(|(_, g)| g);
    let jobs: Vec<Job> = graphs
        .iter()
        .enumerate()
        .map(|(id, (name, _))| Job {
            id,
            algorithm: if id % 2 == 0 { "auto".into() } else { "C-2".into() },
            graph_name: name.clone(),
        })
        .collect();
    let coord = Coordinator { workers: 4, algorithm_threads: 1 };
    let reports = coord.run_batch(jobs, lookup).unwrap();
    assert_eq!(reports.len(), graphs.len());
    for r in &reports {
        let g = lookup(&r.graph_name).unwrap();
        let want = cc::num_components(&cc::ground_truth(g));
        assert_eq!(r.components, want, "{} via {}", r.graph_name, r.algorithm);
    }
}

/// Policy sanity on the class extremes.
#[test]
fn auto_policy_class_extremes() {
    let road = stats::stats(&gen::road(200, 200, 2).into_csr());
    assert_eq!(auto_select(&road).name(), "C-m");
    let social = stats::stats(&gen::rmat(12, 40_000, gen::RmatKind::Graph500, 3).into_csr());
    assert!(matches!(auto_select(&social).name().as_str(), "C-1" | "C-2"));
}

/// Real files through the io layer feed the algorithms end to end.
#[test]
fn file_to_labels_pipeline() {
    let dir = std::env::temp_dir().join("contour_integration_io");
    std::fs::create_dir_all(&dir).unwrap();
    let e = gen::component_soup(5, 40, 3);
    let mtx = dir.join("soup.mtx");
    io::write_mtx(&mtx, &e).unwrap();
    let g = io::read_auto(&mtx).unwrap().into_csr();
    let labels = cc::contour::Contour::c2().run(&g);
    assert_eq!(cc::num_components(&labels), 5);

    let bin = dir.join("soup.bin");
    io::write_bin(&bin, &e).unwrap();
    let g2 = io::read_auto(&bin).unwrap().into_csr();
    assert_eq!(cc::contour::Contour::cm().run(&g2), labels);
}

/// EdgeList dedup + CSR invariants on messy input.
#[test]
fn messy_input_normalization() {
    let mut e = EdgeList::new(50);
    // Duplicates, reversed duplicates, self loops.
    for i in 0..49u32 {
        e.push(i, i + 1);
        e.push(i + 1, i);
        e.push(i, i);
    }
    let g = e.into_csr();
    assert_eq!(g.m(), 49);
    let labels = cc::contour::Contour::c2().run(&g);
    assert!(labels.iter().all(|&l| l == 0));
}

/// Figure drivers produce files on a quick corpus (uses the suite with
/// a temp cache; this is the `bench` pipeline smoke test).
#[test]
fn figure_pipeline_quick_smoke() {
    std::env::set_var("CONTOUR_CACHE", std::env::temp_dir().join("contour_fig_cache"));
    let out = std::env::temp_dir().join("contour_fig_out");
    let _ = std::fs::remove_dir_all(&out);
    // Only the cheapest driver here (full sweeps live in `cargo bench`):
    let rendered = contour::bench::figures::table1(&out, true).unwrap();
    assert!(rendered.contains("delaunay_n10"));
    assert!(out.join("table1.csv").exists());
    assert!(out.join("table1.txt").exists());
    std::env::remove_var("CONTOUR_CACHE");
}

/// Distributed simulator trends (§IV-G) on a mid-size delaunay.
#[test]
fn distsim_trends() {
    use contour::distsim::{simulate, CostModel, DistAlgorithm};
    let g = gen::delaunay(4_000, 4).into_csr().shuffled_edges(5);
    let cost = CostModel::default();
    let c1 = simulate(&g, 8, DistAlgorithm::Contour { hops: 1 }, cost);
    let c2 = simulate(&g, 8, DistAlgorithm::Contour { hops: 2 }, cost);
    let uf = simulate(&g, 8, DistAlgorithm::UnionFind, cost);
    assert!(c2.supersteps < c1.supersteps);
    assert!(
        c1.remote_reads / c1.supersteps as u64 <= c2.remote_reads / c2.supersteps as u64,
        "C-1 locality"
    );
    assert_eq!(uf.supersteps, 1);
}
