//! Trace/counter coherence: the span timeline a traced run records must
//! reconcile *exactly* with the `FrontierStats` the same run reports —
//! on all three frontier engines. The trace is an observability layer
//! over the pass loop, not a second bookkeeping system; if the two ever
//! disagree, one of them is lying about what the engine did.
//!
//! Reconciliation rules (see `cc/contour.rs`: a pass span's `detail` is
//! the mode the pass *executed* — a chunk engine's forced backstop
//! sweep traces as "full"):
//!
//! * pass spans == iterations (every pass is on the timeline),
//! * spans with detail chunk|exact == `frontier.passes`,
//! * spans with detail exact == `frontier.exact_passes`,
//! * full spans (chunk engine) == `frontier.full_sweeps`,
//! * Σ `skipped` over partial spans == `frontier.skipped_chunks`,
//! * Σ `lowered` over exact spans == `frontier.activations`.

use std::sync::Arc;

use contour::cc::contour::{ChunkIndexCache, Contour, FrontierMode};
use contour::cc::{Algorithm, RunContext, RunResult};
use contour::graph::{gen, Csr, EdgeList};
use contour::obs::{RunTrace, Span};
use contour::shard::{run_sharded_ctx, ShardedGraph};
use contour::VId;

/// A graph with real frontier structure: a star that quiesces early
/// (so partial passes actually skip chunks) plus an ER tangle.
fn testbed() -> Csr {
    let star = 3_000usize;
    let mut e = EdgeList::with_capacity(star + 4_000, star + 7_000);
    for i in 1..star {
        e.push(0, i as VId);
    }
    for (u, v) in gen::erdos_renyi(4_000, 7_000, 11).into_csr().edges() {
        e.push(u + star as VId, v + star as VId);
    }
    e.into_csr().shuffled_edges(5)
}

fn pass_spans(spans: &[Span]) -> Vec<&Span> {
    spans.iter().filter(|s| s.cat == "contour" && s.name.starts_with("pass")).collect()
}

fn assert_coherent(r: &RunResult, mode: FrontierMode) {
    let tr = r.trace.as_ref().expect("traced run must carry its trace");
    assert_eq!(tr.dropped(), 0, "{mode:?}: spans dropped");
    let spans = tr.spans();
    let passes = pass_spans(&spans);
    assert_eq!(passes.len(), r.iterations, "{mode:?}: one span per pass");
    let by = |d: &str| passes.iter().filter(|s| s.detail == d).count() as u64;
    assert_eq!(by("chunk") + by("exact"), r.frontier.passes, "{mode:?}: partial passes");
    assert_eq!(by("exact"), r.frontier.exact_passes, "{mode:?}: exact passes");
    if mode == FrontierMode::Chunk {
        assert_eq!(by("full"), r.frontier.full_sweeps, "backstop sweeps");
    }
    if mode == FrontierMode::Off {
        assert_eq!(by("full"), passes.len() as u64, "off engine only full-sweeps");
        assert_eq!(r.frontier, Default::default(), "off engine counts nothing");
    }
    let skipped: u64 = passes
        .iter()
        .filter(|s| s.detail != "full")
        .map(|s| s.arg("skipped").expect("pass spans carry `skipped`"))
        .sum();
    assert_eq!(skipped, r.frontier.skipped_chunks, "{mode:?}: skipped chunks");
    let lowered: u64 = passes.iter().filter_map(|s| s.arg("lowered")).sum();
    assert_eq!(lowered, r.frontier.activations, "{mode:?}: activations");
    // The epilogue is always on the timeline.
    assert!(spans.iter().any(|s| s.name == "finalize"), "{mode:?}: finalize span");
}

#[test]
fn traced_spans_reconcile_with_frontier_stats_on_every_engine() {
    let g = testbed();
    let mut labels = None;
    for mode in [FrontierMode::Off, FrontierMode::Chunk, FrontierMode::Exact] {
        let r = Contour::c2().with_frontier_mode(mode).run_traced(&g);
        assert_coherent(&r, mode);
        if mode == FrontierMode::Exact {
            assert!(
                r.trace.as_ref().unwrap().spans().iter().any(|s| s.name == "index"),
                "exact runs trace the index build"
            );
        }
        // Tracing never changes the answer.
        let l = labels.get_or_insert_with(|| r.labels.clone());
        assert_eq!(*l, r.labels, "{mode:?}");
    }
}

#[test]
fn untraced_runs_carry_no_trace() {
    let g = gen::path(500).into_csr();
    let r = Contour::c2().run_with_stats(&g);
    assert!(r.trace.is_none());
    // run_ctx without a trace is the plain path too.
    let r = Contour::c2().run_ctx(&g, &RunContext::default());
    assert!(r.trace.is_none());
}

#[test]
fn chunk_index_cache_is_reused_across_runs() {
    let g = testbed();
    let cache = ChunkIndexCache::default();
    let alg = Contour::c2().with_frontier_mode(FrontierMode::Exact);
    let ctx = RunContext { trace: None, tid: 0, chunk_index_cache: Some(&cache) };
    let r1 = alg.run_ctx(&g, &ctx);
    assert_eq!(cache.reuses(), 0, "first run builds");
    let r2 = alg.run_ctx(&g, &ctx);
    assert_eq!(cache.reuses(), 1, "second run reuses the vertex→chunk index");
    assert_eq!(r1.labels, r2.labels);
}

#[test]
fn sharded_runs_share_one_timeline_across_tracks() {
    let g = gen::erdos_renyi(1_200, 2_000, 3).into_csr();
    let p = 3usize;
    let sg = ShardedGraph::partition(&g, p);
    let tr = Arc::new(RunTrace::new());
    let r = run_sharded_ctx(&sg, &Contour::c2(), 0, Some(&tr));
    assert!(Arc::ptr_eq(r.trace.as_ref().unwrap(), &tr));
    let spans = tr.spans();
    // The whole run is one driver-track span carrying the shard count.
    let pcc = spans.iter().find(|s| s.name == "pcc").expect("driver span");
    assert_eq!(pcc.tid, 0);
    assert_eq!(pcc.arg("shards"), Some(p as u64));
    assert_eq!(pcc.arg("iterations"), Some(r.iterations as u64));
    // One span per shard, each on its own track (tid k + 1), and every
    // shard-local pass span lands on its shard's track.
    let mut shard_iters = 0u64;
    for k in 0..p {
        let s = spans
            .iter()
            .find(|s| s.name == format!("shard{k}"))
            .unwrap_or_else(|| panic!("missing shard{k} span"));
        assert_eq!(s.tid, k as u32 + 1);
        shard_iters += s.arg("iterations").expect("shard spans carry iterations");
    }
    let passes = pass_spans(&spans);
    assert_eq!(passes.len() as u64, shard_iters, "pass spans == Σ shard iterations");
    assert!(passes.iter().all(|s| s.tid >= 1 && s.tid <= p as u32));
    // The boundary merge traces on the driver track.
    if r.boundary_edges > 0 {
        let m = spans.iter().find(|s| s.name == "merge").expect("merge span");
        assert_eq!(m.tid, 0);
        assert_eq!(m.arg("boundary"), Some(r.boundary_edges as u64));
    }
    // And the sharded labels still match the single-shard run.
    assert_eq!(r.labels, Contour::c2().run(&g));
}

#[test]
fn chrome_export_of_a_real_run_has_the_required_keys() {
    let g = testbed();
    let r = Contour::c2().with_frontier_mode(FrontierMode::Exact).run_traced(&g);
    let json = r.trace.unwrap().to_chrome_json("trace_obs test");
    let keys =
        ["\"displayTimeUnit\"", "\"traceEvents\"", "\"ph\":\"X\"", "\"ph\":\"M\"", "\"ts\":"];
    for key in keys {
        assert!(json.contains(key), "missing {key} in {json}");
    }
    assert!(json.contains("\"mode\":\"exact\""), "pass spans carry their mode");
    assert_eq!(json.matches('{').count(), json.matches('}').count(), "unbalanced JSON braces");
}
