//! `cargo bench --bench figures` — regenerates every table and figure of
//! the paper (§IV) and writes them under `results/`.
//!
//! By default runs the *quick* corpus (a few minutes); set
//! `CONTOUR_BENCH_FULL=1` for the full 32-graph Table I corpus.
//! (The image has no criterion; this is a `harness = false` driver over
//! the crate's own measurement harness.)

use std::path::Path;

use contour::bench::figures;

fn main() {
    let full = std::env::var("CONTOUR_BENCH_FULL").map(|v| v == "1").unwrap_or(false);
    let quick = !full;
    let out = Path::new("results");
    let threads = 0; // all cores
    println!(
        "regenerating paper tables/figures ({} corpus) into {}/",
        if quick { "quick" } else { "full" },
        out.display()
    );
    for (name, f) in [
        ("table1", Box::new(|| figures::table1(out, quick)) as Box<dyn Fn() -> anyhow::Result<String>>),
        ("fig1 (iterations)", Box::new(move || figures::fig1(out, quick, threads))),
        ("fig2 (time)", Box::new(move || figures::fig2(out, quick, threads))),
        ("fig3 (speedup vs FastSV)", Box::new(move || figures::fig3(out, quick, threads))),
        ("fig4 (speedup vs ConnectIt)", Box::new(move || figures::fig4(out, quick, threads))),
        ("delaunay scaling", Box::new(move || figures::delaunay_scaling(out, quick, threads))),
        ("distsim (§IV-G)", Box::new(move || figures::distsim_report(out, quick))),
    ] {
        println!("\n==== {name} ====");
        match f() {
            Ok(text) => println!("{text}"),
            Err(e) => println!("FAILED: {e:#}"),
        }
    }
    // PJRT path needs artifacts; report rather than fail without them.
    println!("\n==== pjrt engine ====");
    match figures::pjrt_report(out) {
        Ok(text) => println!("{text}"),
        Err(e) => println!("skipped: {e:#}"),
    }
}
