//! `cargo bench --bench hotpath` — microbenchmarks of the per-edge hot
//! path: operator order, write mode (plain vs CAS, §III-B.3), update
//! mode (async vs sync, §III-B.1), early-check cost (§III-B.2), and
//! thread scaling. This is the profile the §Perf optimization loop in
//! EXPERIMENTS.md iterates on.

use contour::bench::{measure, Table};
use contour::cc::contour::{Contour, UpdateMode, WriteMode};
use contour::cc::Algorithm;
use contour::graph::gen;
use contour::par;

fn main() {
    let g = gen::rmat(18, 1 << 22, gen::RmatKind::Graph500, 1).into_csr();
    let road = gen::road(700, 700, 2).into_csr().shuffled_edges(3);
    println!("rmat: n={} m={} | road: n={} m={}\n", g.n, g.m(), road.n, road.m());

    let mut t = Table::new(&["bench", "graph", "median_ms", "medges_per_s"]);
    let mut bench = |name: &str, gname: &str, graph: &contour::graph::Csr, alg: Contour| {
        let mut iters = 0usize;
        let s = measure(1, 3, || iters = alg.run_with_stats(graph).iterations);
        let medges = graph.m() as f64 * iters as f64 / s.median_ms / 1e3;
        t.row(vec![
            name.into(),
            gname.into(),
            format!("{:.2}", s.median_ms),
            format!("{medges:.1}"),
        ]);
    };

    // Operator order (ablation for Fig. 1's cost story).
    for (name, alg) in [
        ("order/C-1", Contour::c1()),
        ("order/C-2", Contour::c2()),
        ("order/C-m", Contour::cm()),
        ("order/C-11mm", Contour::c11mm()),
    ] {
        bench(name, "rmat", &g, alg.clone());
        bench(name, "road", &road, alg);
    }
    // Write mode (§III-B.3: plain stores vs CAS).
    bench("write/plain", "rmat", &g, Contour::c2().with_write(WriteMode::Plain));
    bench("write/cas", "rmat", &g, Contour::c2().with_write(WriteMode::Cas));
    // Update mode (§III-B.1: async vs sync L_u).
    bench("update/async", "rmat", &g, Contour::c2());
    bench("update/sync", "rmat", &g, Contour::c2().with_update(UpdateMode::Sync).with_write(WriteMode::Cas));
    // Early check (§III-B.2).
    bench("early/on", "road", &road, Contour::c2().with_early_check(true));
    bench("early/off", "road", &road, Contour::c2().with_early_check(false));
    // Thread scaling.
    for threads in [1usize, 2, 4, 8, 16] {
        bench(&format!("threads/{threads}"), "rmat", &g, Contour::c2().with_threads(threads));
    }
    // Parallel substrate (pool PR): persistent worker pool vs the old
    // spawn-per-call scoped threads, same C-2 runs on three shapes with
    // different pass profiles — rmat (few heavy passes), shuffled path
    // (many passes, so spawn/join churn is paid O(log d) times), road
    // (mid-diameter). The pool amortizes thread startup across passes.
    let pathg = gen::path(1 << 19).into_csr().shuffled_edges(9);
    for (mode, label) in
        [(par::ExecMode::SpawnPerCall, "spawn"), (par::ExecMode::Pooled, "pool")]
    {
        par::set_exec_mode(mode);
        bench(&format!("exec/{label}"), "rmat", &g, Contour::c2());
        bench(&format!("exec/{label}"), "path", &pathg, Contour::c2());
        bench(&format!("exec/{label}"), "road", &road, Contour::c2());
    }
    par::set_exec_mode(par::ExecMode::Pooled);
    let pool = par::pool::stats();
    println!(
        "pool: workers={} jobs={} pulls={} parks={} wakes={}\n",
        pool.workers, pool.jobs, pool.pulls, pool.parks, pool.wakes
    );
    // Baselines for context.
    for name in ["FastSV", "ConnectIt"] {
        let alg = contour::coordinator::algorithm_by_name(name, 0).unwrap();
        let mut iters = 0usize;
        let s = measure(1, 3, || iters = alg.run_with_stats(&g).iterations);
        t.row(vec![
            format!("baseline/{name}"),
            "rmat".into(),
            format!("{:.2}", s.median_ms),
            format!("{:.1}", g.m() as f64 * iters as f64 / s.median_ms / 1e3),
        ]);
    }

    println!("{}", t.render());
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/hotpath.txt", t.render()).ok();
    std::fs::write("results/hotpath.csv", t.csv()).ok();
}
