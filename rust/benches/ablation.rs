//! `cargo bench --bench ablation` — design-choice ablations DESIGN.md
//! calls out:
//!
//! 1. the ConnectIt design space (sampling × find × unite — the paper's
//!    comparator is itself a framework; we sweep all 18 points),
//! 2. Contour schedule parameters (C-11mm warmup length, C-m order),
//! 3. incremental vs static connectivity,
//! 4. PJRT per-iteration vs fused dispatch (when artifacts exist).

use contour::bench::{measure, Table};
use contour::cc::connectit::ConnectItVariant;
use contour::cc::contour::{Contour, Schedule};
use contour::cc::incremental::IncrementalCc;
use contour::cc::Algorithm;
use contour::graph::gen;

fn main() {
    let social = gen::rmat(16, 1 << 20, gen::RmatKind::Graph500, 1).into_csr();
    let road = gen::road(400, 400, 2).into_csr().shuffled_edges(3);
    println!("social: n={} m={} | road: n={} m={}\n", social.n, social.m(), road.n, road.m());

    // ---- 1. ConnectIt design space.
    let mut t = Table::new(&["variant", "social_ms", "road_ms"]);
    for v in ConnectItVariant::design_space() {
        let s1 = measure(1, 3, || {
            v.run(&social);
        });
        let s2 = measure(1, 3, || {
            v.run(&road);
        });
        t.row(vec![
            v.short_name(),
            format!("{:.2}", s1.median_ms),
            format!("{:.2}", s2.median_ms),
        ]);
    }
    println!("== ConnectIt design space ==\n{}", t.render());
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/ablation_connectit.txt", t.render()).ok();

    // ---- 2. Contour schedule parameters.
    let mut t = Table::new(&["schedule", "graph", "iterations", "median_ms"]);
    let mut sched = |name: String, schedule: Schedule, gname: &str, g: &contour::graph::Csr| {
        let alg = Contour::c2();
        let mut alg = alg;
        alg.schedule = schedule;
        let mut iters = 0usize;
        let s = measure(1, 3, || iters = alg.run_with_stats(g).iterations);
        t.row(vec![name, gname.into(), iters.to_string(), format!("{:.2}", s.median_ms)]);
    };
    for m_order in [4usize, 16, 64, 1024] {
        sched(format!("C-m(m={m_order})"), Schedule::Fixed(m_order), "road", &road);
    }
    for ones in [1usize, 2, 4, 8] {
        sched(
            format!("C-11mm(ones={ones})"),
            Schedule::OnesThenM { ones, m: 1024 },
            "road",
            &road,
        );
    }
    for m_order in [16usize, 1024] {
        sched(format!("C-1m1m(m={m_order})"), Schedule::Alternate { m: m_order }, "road", &road);
    }
    println!("== Contour schedule parameters ==\n{}", t.render());
    std::fs::write("results/ablation_schedule.txt", t.render()).ok();

    // ---- 3. Incremental vs static.
    let mut t = Table::new(&["mode", "median_ms"]);
    let s_static = measure(1, 3, || {
        IncrementalCc::from_graph(&social, 0);
    });
    t.row(vec!["bulk-seed".into(), format!("{:.2}", s_static.median_ms)]);
    let edges: Vec<_> = social.edges().collect();
    let s_inc = measure(0, 1, || {
        let idx = IncrementalCc::new(social.n);
        for &(u, v) in &edges {
            idx.add_edge(u, v);
        }
    });
    t.row(vec!["online-inserts".into(), format!("{:.2}", s_inc.median_ms)]);
    println!("== incremental connectivity ==\n{}", t.render());
    std::fs::write("results/ablation_incremental.txt", t.render()).ok();

    // ---- 4. PJRT dispatch granularity.
    match contour::runtime::Runtime::from_env() {
        Ok(rt) => {
            use contour::coordinator::{PjrtContour, PjrtMode};
            let g = gen::delaunay(1 << 14, 7).into_csr();
            let mut t = Table::new(&["engine", "median_ms"]);
            for mode in [PjrtMode::PerIteration, PjrtMode::FusedRun] {
                let eng = PjrtContour::new(&rt, 2, mode);
                let s = measure(1, 3, || {
                    eng.try_run(&g).unwrap();
                });
                t.row(vec![eng.name(), format!("{:.2}", s.median_ms)]);
            }
            println!("== PJRT dispatch granularity (delaunay n14) ==\n{}", t.render());
            std::fs::write("results/ablation_pjrt.txt", t.render()).ok();
        }
        Err(e) => println!("PJRT ablation skipped: {e}"),
    }
}
