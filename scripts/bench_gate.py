#!/usr/bin/env python3
"""Bench regression gate: diff a fresh bench JSON against the committed
repo-root baseline with per-metric tolerance bands.

    python3 scripts/bench_gate.py --bench hotpath \
        --current results/BENCH_hotpath.json --baseline BENCH_hotpath.json
    python3 scripts/bench_gate.py --bench serving \
        --current results/BENCH_serving.json --baseline BENCH_serving.json

Prints a trajectory table (and appends it to $GITHUB_STEP_SUMMARY when
set). While the committed baseline has no records the gate is
warn-only: it reports the fresh numbers and exits 0, so the trajectory
can be seeded from CI artifacts without a chicken-and-egg failure.
Once the baseline is populated, a metric outside its band fails the
job (exit 1); `--warn-only` downgrades that to a warning.

Timing bands are deliberately loose (shared CI runners are noisy);
deterministic metrics (per-shard edge-mass balance) get tight bands.
"""

import argparse
import json
import os
import sys

# (metric key, direction, band) — "higher" means bigger is better and
# the gate fails when current < baseline * band; "lower" means smaller
# is better and the gate fails when current > baseline * band.
HOTPATH_BANDS = [
    ("frontier_speedup_rmat", "higher", 0.80),
    ("exact_vs_chunk_rmat", "higher", 0.80),
    ("exact_vs_chunk_road", "higher", 0.80),
    ("edge_mass_ratio_p4_vertices", "lower", 1.05),
    ("edge_mass_ratio_p4_edges", "lower", 1.05),
]

SERVING_BANDS = [
    ("qps", "higher", 0.75),
    ("vertices_per_sec", "higher", 0.75),
    ("p95_us", "lower", 1.50),
    ("p99_us", "lower", 2.00),
]


def fmt(v):
    if v is None:
        return "-"
    if isinstance(v, float) and v != int(v):
        return f"{v:.3f}"
    return f"{v:.0f}" if isinstance(v, float) else str(v)


def judge(cur, base, direction, band):
    """Return (status, detail). Status: ok | REGRESSED | new | missing."""
    if cur is None:
        return "missing", "metric absent from fresh run"
    if base is None:
        return "new", "no baseline value"
    if base == 0:
        return "ok", "baseline zero, skipped"
    ratio = cur / base
    if direction == "higher":
        bad = ratio < band
        detail = f"{ratio:.2f}x vs floor {band:.2f}x"
    else:
        bad = ratio > band
        detail = f"{ratio:.2f}x vs ceiling {band:.2f}x"
    return ("REGRESSED" if bad else "ok"), detail


def gate_hotpath(cur, base):
    cs, bs = cur.get("summary", {}), base.get("summary", {})
    rows = []
    for key, direction, band in HOTPATH_BANDS:
        status, detail = judge(cs.get(key), bs.get(key), direction, band)
        rows.append((key, fmt(bs.get(key)), fmt(cs.get(key)), status, detail))
    return rows


def gate_serving(cur, base):
    def by_scenario(doc):
        return {r.get("scenario"): r for r in doc.get("records", [])}

    cs, bs = by_scenario(cur), by_scenario(base)
    rows = []
    for scenario in sorted(cs):
        crec, brec = cs[scenario], bs.get(scenario, {})
        for key, direction, band in SERVING_BANDS:
            status, detail = judge(crec.get(key), brec.get(key), direction, band)
            rows.append((f"{scenario} {key}", fmt(brec.get(key)), fmt(crec.get(key)),
                         status, detail))
    for scenario in sorted(set(bs) - set(cs)):
        rows.append((scenario, "present", "-", "missing", "scenario absent from fresh run"))
    return rows


def render(rows, title):
    lines = [f"### Bench gate: {title}", "",
             "| metric | baseline | current | status | band |",
             "|---|---:|---:|---|---|"]
    for name, b, c, status, detail in rows:
        lines.append(f"| {name} | {b} | {c} | {status} | {detail} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench", choices=["hotpath", "serving"], required=True)
    ap.add_argument("--current", required=True)
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--warn-only", action="store_true",
                    help="report regressions but always exit 0")
    args = ap.parse_args()

    with open(args.current) as f:
        cur = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)

    seeded = bool(base.get("records"))
    gate = gate_hotpath if args.bench == "hotpath" else gate_serving
    rows = gate(cur, base)

    table = render(rows, args.bench)
    if not seeded:
        table += ("\n\nBaseline has no records yet — warn-only. Refresh the committed "
                  f"{os.path.basename(args.baseline)} from this run's artifact to arm the gate.")
    print(table)

    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        with open(summary_path, "a") as f:
            f.write(table + "\n\n")

    regressed = [r for r in rows if r[3] in ("REGRESSED", "missing")]
    if regressed and seeded:
        for name, _, _, status, detail in regressed:
            print(f"::warning::{args.bench}: {name} {status} ({detail})")
        if args.warn_only:
            print("gate: regressions found, but --warn-only is set")
            return 0
        print(f"gate: FAIL — {len(regressed)} metric(s) outside tolerance")
        return 1
    print("gate: pass" if seeded else "gate: pass (unseeded baseline, warn-only)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
